"""Explicit request/lane lifecycle state machines with enforced transitions.

PR 1–9 grew the dispatcher's request bookkeeping as implicit flags —
``req.done``, ``req.error``, ``lane.retired``, ``lane.finalizing`` — which
is fine until the control plane has to be *restartable*: a journal can
only replay what was recorded as a well-defined state, and recovery can
only requeue work whose progress it can classify.  This module makes the
states explicit and the transitions enforced:

Request lifecycle::

    SUBMITTED ──► QUEUED ──► GRANTED ──► STEPPING ──► COMPLETED
        │            │           │            │
        │            ├──► SHED   │            ├──► FAILED
        └──► FAILED  └──► FAILED └──► FAILED  └──► INTERRUPTED ─┐
                     ▲           └──► PREEMPTED ─┐              │
                     └───────────────────────────┴──────────────┘
                                  (both re-enter QUEUED on recovery)

* ``SUBMITTED`` — constructed and charged against backpressure; not yet
  durable.  An admission rejection fails it here (never journaled).
* ``QUEUED`` — appended to a lane FIFO; this is the durability point
  (the journal writes the full request record).
* ``GRANTED`` — a scheduling quantum popped it from the FIFO.
* ``STEPPING`` — handed to the engine; tokens may exist from here on.
* ``COMPLETED`` / ``FAILED`` / ``SHED`` — terminal.
* ``PREEMPTED`` — its grant was revoked before the engine saw it (today:
  only by a crash between grant and seat); re-enters ``QUEUED``.
* ``INTERRUPTED`` — it was ``STEPPING`` when the process died; recovery
  marks it so resubmission is explicit and idempotent (deterministic
  engines regenerate the same tokens from a fresh seat), then requeues.

Lane lifecycle: ``REGISTERED → ACTIVE → RETIRING → RETIRED`` (a lane may
retire straight from ``REGISTERED`` if it never served work).

:class:`LifecycleTracker` is the enforcement point the dispatcher calls
on every transition: it validates the move against the tables above
(raising :class:`~repro.dispatch.errors.IllegalTransition` on a violation),
stamps the new state onto the request/lane, gives an attached
:class:`~repro.dispatch.journal.FaultInjector` its crash-at-transition
hook, and enqueues a journal record.  The tracker itself never touches
SQLite — journal appends are O(1) in-memory handoffs to the journal's
writer thread, so transitions are safe to perform near (though by
convention still outside) the dispatcher's hot locks.
"""

from __future__ import annotations

from typing import Any, Optional

from .errors import IllegalTransition


class RequestState:
    """Request lifecycle state names (plain strings, journal-friendly)."""

    SUBMITTED = "submitted"
    QUEUED = "queued"
    GRANTED = "granted"
    STEPPING = "stepping"
    COMPLETED = "completed"
    FAILED = "failed"
    SHED = "shed"
    PREEMPTED = "preempted"
    INTERRUPTED = "interrupted"


class LaneState:
    """Lane lifecycle state names."""

    REGISTERED = "registered"
    ACTIVE = "active"
    RETIRING = "retiring"
    RETIRED = "retired"


#: Terminal request states: no transition leaves them.
TERMINAL_STATES = frozenset(
    {RequestState.COMPLETED, RequestState.FAILED, RequestState.SHED}
)

#: Legal request transitions: ``{src: allowed dst set}``.
REQUEST_TRANSITIONS: dict = {
    RequestState.SUBMITTED: frozenset(
        {RequestState.QUEUED, RequestState.FAILED}
    ),
    RequestState.QUEUED: frozenset(
        {RequestState.GRANTED, RequestState.SHED, RequestState.FAILED}
    ),
    RequestState.GRANTED: frozenset(
        {RequestState.STEPPING, RequestState.PREEMPTED, RequestState.FAILED}
    ),
    RequestState.STEPPING: frozenset(
        {
            RequestState.COMPLETED,
            RequestState.FAILED,
            RequestState.INTERRUPTED,
        }
    ),
    RequestState.PREEMPTED: frozenset({RequestState.QUEUED}),
    RequestState.INTERRUPTED: frozenset({RequestState.QUEUED}),
    RequestState.COMPLETED: frozenset(),
    RequestState.FAILED: frozenset(),
    RequestState.SHED: frozenset(),
}

#: Legal lane transitions: ``{src: allowed dst set}``.
LANE_TRANSITIONS: dict = {
    LaneState.REGISTERED: frozenset({LaneState.ACTIVE, LaneState.RETIRING}),
    LaneState.ACTIVE: frozenset({LaneState.RETIRING}),
    LaneState.RETIRING: frozenset({LaneState.RETIRED}),
    LaneState.RETIRED: frozenset(),
}


def check_request_transition(src: str, dst: str, *, rid: Any = None) -> None:
    """Validate one request transition, raising
    :class:`~repro.dispatch.errors.IllegalTransition` if the state machine
    forbids it.  Unknown source states are illegal by definition."""
    allowed = REQUEST_TRANSITIONS.get(src)
    if allowed is None or dst not in allowed:
        raise IllegalTransition("request", rid, src, dst)


def check_lane_transition(src: str, dst: str, *, name: str = "") -> None:
    """Validate one lane transition (same contract as
    :func:`check_request_transition`)."""
    allowed = LANE_TRANSITIONS.get(src)
    if allowed is None or dst not in allowed:
        raise IllegalTransition("lane", name, src, dst)


class LifecycleTracker:
    """The dispatcher's transition enforcement point.

    One instance per dispatcher.  ``journal`` (a
    :class:`~repro.dispatch.journal.RequestJournal`) and ``faults`` (a
    :class:`~repro.dispatch.journal.FaultInjector`) are both optional;
    with neither attached a transition costs a dict probe and an
    attribute store.  Requests the dispatcher never admitted (work
    submitted straight to an engine) carry no state and are ignored —
    enforcement covers exactly the requests the control plane owns.
    """

    def __init__(
        self, *, journal: Optional[Any] = None, faults: Optional[Any] = None
    ) -> None:
        self.journal = journal
        self.faults = faults

    # -- requests ----------------------------------------------------------

    def begin(self, req: Any) -> None:
        """Stamp a freshly admitted request as ``SUBMITTED`` (the state
        machine's origin; no legality check — a request begins once)."""
        req.state = RequestState.SUBMITTED

    def advance(self, req: Any, dst: str, *, lane: str = "") -> bool:
        """Move ``req`` to state ``dst``, enforcing legality.

        Returns ``False`` (a silent no-op) for untracked requests (no
        ``state``) and for same-state re-entries; raises
        :class:`~repro.dispatch.errors.IllegalTransition` for a forbidden
        move.  On success: stamps ``req.state``, fires the fault
        injector's crash-at-transition hook, and appends the journal
        record (full request row at ``QUEUED`` — the durability point —
        a bare transition row for every later state)."""
        src = getattr(req, "state", "")
        if not src:
            return False
        if src == dst:
            return False
        check_request_transition(src, dst, rid=getattr(req, "rid", None))
        req.state = dst
        if self.faults is not None:
            self.faults.on_transition("request", getattr(req, "rid", None), dst)
        if self.journal is not None:
            if dst == RequestState.QUEUED and not getattr(
                req, "_journaled", False
            ):
                self.journal.record_request(req, lane)
                req._journaled = True
            elif getattr(req, "_journaled", False):
                self.journal.record_transition(req.rid, dst)
        return True

    def is_terminal(self, req: Any) -> bool:
        """Whether ``req`` has reached a terminal state (untracked
        requests report ``False``)."""
        return getattr(req, "state", "") in TERMINAL_STATES

    # -- lanes -------------------------------------------------------------

    def lane_begin(
        self,
        lane: Any,
        *,
        spec: Optional[Any] = None,
        weight: float = 1.0,
        priority_class: int = 0,
        latency_target_ms: Optional[float] = None,
    ) -> None:
        """Stamp a fresh lane ``REGISTERED`` and journal its registration
        (with the picklable engine recipe, when one was provided — that
        recipe is what :meth:`Dispatcher.recover` rebuilds the engine
        from)."""
        lane.lc_state = LaneState.REGISTERED
        if self.faults is not None:
            self.faults.on_transition("lane", lane.name, LaneState.REGISTERED)
        if self.journal is not None:
            self.journal.record_lane(
                lane.name,
                LaneState.REGISTERED,
                spec=spec,
                weight=weight,
                priority_class=priority_class,
                latency_target_ms=latency_target_ms,
            )

    def lane_advance(self, lane: Any, dst: str) -> bool:
        """Move a lane to state ``dst`` (same contract as
        :meth:`advance`; lanes created before a tracker was attached are
        untracked and ignored)."""
        src = getattr(lane, "lc_state", "")
        if not src:
            return False
        if src == dst:
            return False
        check_lane_transition(src, dst, name=lane.name)
        lane.lc_state = dst
        if self.faults is not None:
            self.faults.on_transition("lane", lane.name, dst)
        if self.journal is not None:
            self.journal.record_lane(lane.name, dst)
        return True
