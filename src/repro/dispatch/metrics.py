"""Serving metrics: latency percentiles, throughput, cache snapshots.

The quantities a dispatch layer is judged by (GPU-datacenter scheduling
survey, Gao et al.): time-to-first-token (prefill + queueing), per-token
decode latency, end-to-end request latency, aggregate token throughput —
plus the schedule-cache hit statistics that show the AoT pre-run actually
amortizing.  Everything exports as a plain dict so benchmarks and examples
can print or JSON-dump a snapshot.

Thread-safety contract: :class:`DispatchMetrics` is safe to feed from any
number of threads — a background stepping thread observing completions races
foreground submitters calling ``on_submit``/``on_reject`` and monitoring
threads calling ``snapshot`` — one internal lock serializes every
mutation and every aggregate read.  Bare :class:`LatencySeries` objects are
*not* internally locked; they are only mutated under their owner's lock.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Optional

import numpy as np


def percentile(values, q: float) -> float:
    """Linear-interpolated percentile; 0.0 on empty input."""
    if not len(values):
        return 0.0
    return float(np.percentile(np.asarray(values, dtype=np.float64), q))


@dataclasses.dataclass
class LatencySeries:
    """One latency distribution, recorded in seconds.

    ``window`` bounds retention: percentiles are computed over the most
    recent observations (a deque ring, O(1) per record), so a long-running
    service reports current behavior instead of leaking memory linearly
    with traffic.  ``dropped`` counts observations the ring has evicted —
    a windowed p95 over a series that has silently shed most of its
    history is a different claim than one over everything recorded, and
    the summary says which it is."""

    name: str
    values: Any = dataclasses.field(default_factory=list)
    window: int = 65536
    dropped: int = 0

    def __post_init__(self) -> None:
        self.values = deque(self.values, maxlen=self.window)

    def record(self, seconds: float) -> None:
        """Append one observation (in seconds), counting the eviction when
        the bounded window is already full."""
        if len(self.values) == self.window:
            self.dropped += 1
        self.values.append(float(seconds))

    @property
    def count(self) -> int:
        """Observations currently retained (≤ ``window``)."""
        return len(self.values)

    def summary_ms(self) -> dict:
        """Count/mean/p50/p90/p95/p99/max over the retained window (in ms),
        plus ``dropped``: observations the window has evicted."""
        vals = np.asarray(self.values, dtype=np.float64) * 1e3
        if not len(vals):
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0,
                    "p95": 0.0, "p99": 0.0, "max": 0.0,
                    "dropped": self.dropped}
        return {
            "count": int(len(vals)),
            "mean": float(vals.mean()),
            "p50": percentile(vals, 50),
            "p90": percentile(vals, 90),
            "p95": percentile(vals, 95),
            "p99": percentile(vals, 99),
            "max": float(vals.max()),
            "dropped": self.dropped,
        }


@dataclasses.dataclass
class _EngineSeries:
    """Per-engine stepping record: quantum count, tokens, step latency.

    Internal to :class:`DispatchMetrics`; mutated only under its lock."""

    steps: int = 0
    tokens: int = 0
    step_latency: LatencySeries = None

    def __post_init__(self) -> None:
        if self.step_latency is None:
            self.step_latency = LatencySeries("engine_step", window=8192)


class DispatchMetrics:
    """Aggregates per-request observations into a serving-level snapshot.

    Thread-safe: any number of stepper threads may feed
    :meth:`observe_request` / :meth:`on_engine_step` while submitters call
    :meth:`on_submit` / :meth:`on_reject` and monitors call
    :meth:`snapshot` — one internal lock serializes everything.  Per-engine
    stepping makes the per-model breakdown matter: the ``engines`` section
    of the snapshot shows each stepper's quantum count and step-latency
    distribution, so a slow tenant is visible as *its* p99, not a blur in
    the aggregate.
    """

    def __init__(self) -> None:
        self.ttft = LatencySeries("ttft")            # submit -> first token
        self.per_token = LatencySeries("per_token")  # decode time / token
        self.e2e = LatencySeries("e2e")              # submit -> done
        self.requests_done = 0
        self.tokens_out = 0
        self.rejected = 0                             # backpressure refusals
        self.truncated = 0           # finished early: context window filled
        self.failed = 0              # completed with error (never served)
        self._engines: dict = {}                      # model -> _EngineSeries
        self._dropped: set = set()                    # unregistered tombstones
        # quantum-grant latency: lane became grantable -> arbiter granted it
        # (the event-driven hand-off's figure of merit: under contention the
        # p95 must sit below the old 10 ms fallback tick)
        self.grant_latency = LatencySeries("grant", window=65536)
        self._grants = 0
        # per-grant CPU cost: arbiter time spent selecting + bookkeeping
        # per grant issued — the O(1)-grant-path figure of merit (must stay
        # flat as the registered-tenant count grows)
        self.grant_cost = LatencySeries("grant_cost", window=65536)
        # ready-set size samples (indexed ready set, recorded per granting
        # pump): how much of the fleet is actually contending
        self._ready_sizes = deque(maxlen=8192)
        self._ready_peak = 0
        self._ready_dropped = 0          # samples the bounded ring evicted
        # stepper-pool occupancy: busy-worker samples, recorded per grant
        # and — so idle periods appear at all — per fallback tick by the
        # arbiter's designated ticker
        self._pool_size = 0
        self._pool_busy = deque(maxlen=8192)
        self._pool_busy_peak = 0
        self._pool_busy_dropped = 0      # samples the bounded ring evicted
        # batch-composer series: shared cross-tenant decode steps — how
        # full the shared batch ran (slot occupancy), how often a step
        # actually served >1 tenant (coalesce rate), and each tenant's
        # token share of the composed traffic
        self._comp_steps = 0
        self._comp_coalesced = 0         # composed steps serving >= 2 lanes
        self._comp_capacity = 0
        self._comp_occ = deque(maxlen=8192)
        self._comp_occ_peak = 0
        self._comp_occ_dropped = 0
        self._comp_lane_tokens: dict = {}
        self.composed_step_latency = LatencySeries("composed_step", window=8192)
        # SLO / priority-class plane: per-class grant + e2e distributions
        # and the preemption / shed / admission / deadline counters that
        # make overload behavior per class observable (a priority scheme
        # you can't see is one you can't trust)
        self._lane_class: dict = {}          # lane -> priority class
        self._class_grant: dict = {}         # cls -> LatencySeries
        self._class_e2e: dict = {}           # cls -> LatencySeries
        self.preemptions = 0                 # grants not renewed for class
        self._preempt_by_class: dict = {}
        self.shed = 0                        # queued requests load-shed
        self._shed_by_class: dict = {}
        self.admission_rejected = 0          # AdmissionRejected at submit
        self._admission_by_class: dict = {}
        self._deadline_miss: dict = {}       # cls -> completions past target
        self._deadline_total: dict = {}      # cls -> completions with target
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self._mu = threading.Lock()

    def on_submit(self, t_submit: Optional[float] = None) -> None:
        """Record one accepted submission (its timestamp anchors wall time)."""
        t = time.perf_counter() if t_submit is None else t_submit
        with self._mu:
            if self._t_first_submit is None or t < self._t_first_submit:
                self._t_first_submit = t

    def on_reject(self) -> None:
        """Record one backpressure refusal."""
        with self._mu:
            self.rejected += 1

    def on_engine_step(
        self, model: str, seconds: float, *, tokens: int = 0
    ) -> None:
        """Record one engine stepping quantum for ``model``: its wall time
        and the tokens it produced.  Fed by ``Dispatcher.step_lane`` from
        whichever thread stepped the lane.  Tombstoned (unregistered)
        models are ignored — a straggler quantum racing the unregister
        must not resurrect the dropped series."""
        with self._mu:
            if model in self._dropped:
                return
            rec = self._engines.get(model)
            if rec is None:
                rec = self._engines[model] = _EngineSeries()
            rec.steps += 1
            rec.tokens += tokens
            rec.step_latency.record(seconds)

    def on_grant(self, seconds: float, *, lane: Optional[str] = None) -> None:
        """Record one quantum grant: ``seconds`` is the arbiter's reaction
        time — from the latest of the lane becoming ready, its executor
        (blocked stepper / idle pool worker) becoming free, and the last
        grant-enabling event the arbiter processed, to the grant.  Backlog
        behind busy executors and a policy's own rationing (stride holding
        for its top pick) are scheduling decisions, not hand-off delay,
        and are excluded.  Fed by the async layer's arbiter on every
        grant, in every arbitrated stepping mode.  When ``lane`` is given
        and carries a priority class (:meth:`set_lane_class`), the sample
        also lands in that class's grant series — the per-class tail the
        SLO plane is judged by."""
        with self._mu:
            self._grants += 1
            self.grant_latency.record(seconds)
            if lane is not None and lane in self._lane_class:
                cls = self._lane_class[lane]
                series = self._class_grant.get(cls)
                if series is None:
                    series = self._class_grant[cls] = LatencySeries(
                        f"grant_class_{cls}", window=65536
                    )
                series.record(seconds)

    def set_lane_class(self, lane: str, cls: int) -> None:
        """Bind ``lane`` to priority class ``cls`` so grant and e2e
        samples route into per-class series — called by the dispatcher at
        registration (:func:`drop_engine` unbinds)."""
        with self._mu:
            self._lane_class[lane] = int(cls)

    def on_preemption(self, cls: int, n: int = 1) -> None:
        """Count ``n`` quantum-boundary preemptions (grants not renewed)
        suffered by class ``cls`` lanes in favor of a higher class."""
        with self._mu:
            self.preemptions += n
            self._preempt_by_class[cls] = (
                self._preempt_by_class.get(cls, 0) + n
            )

    def on_shed(self, cls: int) -> None:
        """Count one queued class-``cls`` request load-shed because its
        deadline became unmeetable."""
        with self._mu:
            self.shed += 1
            self._shed_by_class[cls] = self._shed_by_class.get(cls, 0) + 1

    def on_admission_reject(self, cls: int) -> None:
        """Count one class-``cls`` submission refused at admission
        (``AdmissionRejected``: the deadline was provably unmeetable)."""
        with self._mu:
            self.admission_rejected += 1
            self._admission_by_class[cls] = (
                self._admission_by_class.get(cls, 0) + 1
            )

    def on_deadline(self, cls: int, missed: bool) -> None:
        """Record one completed class-``cls`` request that carried a
        latency target: ``missed`` says whether it finished past its
        deadline (the deadline-miss series is the ratio of these)."""
        with self._mu:
            self._deadline_total[cls] = self._deadline_total.get(cls, 0) + 1
            if missed:
                self._deadline_miss[cls] = (
                    self._deadline_miss.get(cls, 0) + 1
                )

    def on_grant_cost(self, seconds: float) -> None:
        """Record the arbiter CPU cost attributed to one grant: selection
        plus grant bookkeeping time, divided over the grants the pump
        issued.  This is the per-event cost the indexed grant path keeps
        O(active): flat as registered tenants grow, because neither the
        pump nor the policy walks the registry."""
        with self._mu:
            self.grant_cost.record(seconds)

    def on_ready_size(self, size: int) -> None:
        """Record one indexed-ready-set size sample (taken by the arbiter
        per granting pump): the number of lanes actually contending for
        quanta, as opposed to merely registered."""
        with self._mu:
            if len(self._ready_sizes) == self._ready_sizes.maxlen:
                self._ready_dropped += 1
            self._ready_sizes.append(int(size))
            if size > self._ready_peak:
                self._ready_peak = int(size)

    def drop_engine(self, model: str) -> None:
        """Forget ``model``'s per-engine series (the tenant was
        unregistered): a dead tenant must stop occupying snapshot space
        and per-engine walks forever.  The name is tombstoned so a
        straggler step racing the unregister cannot resurrect the entry
        (:meth:`on_engine_step` ignores tombstoned models);
        :meth:`track_engine` lifts the tombstone on re-registration."""
        with self._mu:
            self._engines.pop(model, None)
            self._comp_lane_tokens.pop(model, None)
            self._lane_class.pop(model, None)
            self._dropped.add(model)

    def track_engine(self, model: str) -> None:
        """(Re-)admit ``model`` to per-engine tracking, lifting any
        tombstone a previous :meth:`drop_engine` left — called by the
        dispatcher at registration so a reused tenant name records
        again."""
        with self._mu:
            self._dropped.discard(model)

    def on_pool_occupancy(self, busy: int, size: int) -> None:
        """Record one stepper-pool occupancy sample: ``busy`` of ``size``
        workers currently executing a granted quantum.  Sampled at each
        grant AND from the arbiter's designated ticker on every fallback
        tick expiry, so the series reflects wall-clock occupancy — an idle
        or parked pool shows up as zeros instead of freezing the series at
        whatever the last grant recorded."""
        with self._mu:
            self._pool_size = size
            if len(self._pool_busy) == self._pool_busy.maxlen:
                self._pool_busy_dropped += 1
            self._pool_busy.append(int(busy))
            if busy > self._pool_busy_peak:
                self._pool_busy_peak = int(busy)

    def on_composed_step(
        self,
        seconds: float,
        *,
        occupied: int,
        capacity: int,
        tokens_by_lane: Any,
    ) -> None:
        """Record one composed (cross-tenant batched) decode step: its wall
        time, how many of the shared batch's ``capacity`` slots were live
        (``occupied``), and the tokens each occupant lane's slots produced.
        Fed by ``Dispatcher.step_group``; the snapshot's ``composer``
        section derives slot occupancy, coalesce rate (fraction of
        composed steps that actually served ≥ 2 tenants), and per-tenant
        shares from these samples."""
        with self._mu:
            self._comp_steps += 1
            self._comp_capacity = capacity
            lanes_served = sum(1 for t in tokens_by_lane.values() if t > 0)
            if lanes_served >= 2:
                self._comp_coalesced += 1
            if len(self._comp_occ) == self._comp_occ.maxlen:
                self._comp_occ_dropped += 1
            self._comp_occ.append(int(occupied))
            if occupied > self._comp_occ_peak:
                self._comp_occ_peak = int(occupied)
            for lane, toks in tokens_by_lane.items():
                if toks and lane not in self._dropped:
                    self._comp_lane_tokens[lane] = (
                        self._comp_lane_tokens.get(lane, 0) + int(toks)
                    )
            self.composed_step_latency.record(seconds)

    def observe_request(self, req: Any) -> None:
        """Fold one finished request (serving ``Request`` timestamps) in,
        counting truncations (context window filled before
        ``max_new_tokens``) and failures (completed with ``error`` set)
        so neither outcome is invisible in the aggregate."""
        ntok = len(req.generated)
        with self._mu:
            self.requests_done += 1
            self.tokens_out += ntok
            if getattr(req, "truncated", False):
                self.truncated += 1
            if getattr(req, "error", None):
                self.failed += 1
            if req.t_first and req.t_submit:
                self.ttft.record(req.t_first - req.t_submit)
            if req.t_done and req.t_submit:
                self.e2e.record(req.t_done - req.t_submit)
                cls = self._lane_class.get(getattr(req, "model", None))
                if cls is not None:
                    series = self._class_e2e.get(cls)
                    if series is None:
                        series = self._class_e2e[cls] = LatencySeries(
                            f"e2e_class_{cls}"
                        )
                    series.record(req.t_done - req.t_submit)
                if ntok > 1 and req.t_first:
                    # decode tokens exclude the one produced by prefill
                    self.per_token.record(
                        (req.t_done - req.t_first) / (ntok - 1)
                    )
            if self._t_last_done is None or req.t_done > self._t_last_done:
                self._t_last_done = req.t_done

    def _wall_locked(self) -> float:
        if self._t_first_submit is None or self._t_last_done is None:
            return 0.0
        return max(0.0, self._t_last_done - self._t_first_submit)

    def _tokens_per_second_locked(self) -> float:
        wall = self._wall_locked()
        return self.tokens_out / wall if wall else 0.0

    def _requests_per_second_locked(self) -> float:
        wall = self._wall_locked()
        return self.requests_done / wall if wall else 0.0

    @property
    def wall_seconds(self) -> float:
        """First submit to last completion, in seconds (0.0 before both)."""
        with self._mu:
            return self._wall_locked()

    @property
    def tokens_per_second(self) -> float:
        """Aggregate decode+prefill token throughput over the wall window."""
        with self._mu:
            return self._tokens_per_second_locked()

    @property
    def requests_per_second(self) -> float:
        """Completed-request throughput over the wall window."""
        with self._mu:
            return self._requests_per_second_locked()

    def snapshot(self, cache_stats: Optional[dict] = None) -> dict:
        """One coherent dict of every aggregate this object tracks,
        including the per-engine ``engines`` breakdown."""
        with self._mu:
            snap = {
                "requests_done": self.requests_done,
                "tokens_out": self.tokens_out,
                "rejected": self.rejected,
                "truncated": self.truncated,
                "failed": self.failed,
                "wall_seconds": self._wall_locked(),
                "tokens_per_second": self._tokens_per_second_locked(),
                "requests_per_second": self._requests_per_second_locked(),
                "ttft_ms": self.ttft.summary_ms(),
                "per_token_ms": self.per_token.summary_ms(),
                "e2e_ms": self.e2e.summary_ms(),
                "grants": self._grants,
                "grant_ms": self.grant_latency.summary_ms(),
                "grant_cost_ms": self.grant_cost.summary_ms(),
                "ready_size": {
                    "mean": (
                        float(np.mean(np.asarray(self._ready_sizes)))
                        if self._ready_sizes else 0.0
                    ),
                    "peak": self._ready_peak,
                    "samples": len(self._ready_sizes),
                    "dropped": self._ready_dropped,
                },
                "engines": {
                    model: {
                        "steps": rec.steps,
                        "tokens": rec.tokens,
                        "step_ms": rec.step_latency.summary_ms(),
                    }
                    for model, rec in self._engines.items()
                },
            }
            snap["preemptions"] = self.preemptions
            snap["shed"] = self.shed
            snap["admission_rejected"] = self.admission_rejected
            if self._lane_class:
                all_classes = sorted(
                    set(self._lane_class.values())
                    | set(self._class_grant)
                    | set(self._class_e2e)
                    | set(self._preempt_by_class)
                    | set(self._shed_by_class)
                    | set(self._admission_by_class)
                    | set(self._deadline_total)
                )
                snap["classes"] = {
                    cls: {
                        "lanes": sorted(
                            l for l, c in self._lane_class.items()
                            if c == cls
                        ),
                        "grant_ms": (
                            self._class_grant[cls].summary_ms()
                            if cls in self._class_grant
                            else LatencySeries("empty").summary_ms()
                        ),
                        "e2e_ms": (
                            self._class_e2e[cls].summary_ms()
                            if cls in self._class_e2e
                            else LatencySeries("empty").summary_ms()
                        ),
                        "preemptions": self._preempt_by_class.get(cls, 0),
                        "shed": self._shed_by_class.get(cls, 0),
                        "admission_rejected": (
                            self._admission_by_class.get(cls, 0)
                        ),
                        "deadline_total": self._deadline_total.get(cls, 0),
                        "deadline_miss": self._deadline_miss.get(cls, 0),
                    }
                    for cls in all_classes
                }
            if self._comp_steps:
                occ = np.asarray(self._comp_occ, dtype=np.float64)
                total_tok = sum(self._comp_lane_tokens.values())
                snap["composer"] = {
                    "steps": self._comp_steps,
                    "coalesced_steps": self._comp_coalesced,
                    "coalesce_rate": self._comp_coalesced / self._comp_steps,
                    "capacity": self._comp_capacity,
                    "occupancy_mean": float(occ.mean()) if len(occ) else 0.0,
                    "occupancy_peak": self._comp_occ_peak,
                    "occupancy_dropped": self._comp_occ_dropped,
                    "step_ms": self.composed_step_latency.summary_ms(),
                    "lane_tokens": dict(self._comp_lane_tokens),
                    "lane_share": {
                        lane: toks / total_tok
                        for lane, toks in self._comp_lane_tokens.items()
                    } if total_tok else {},
                }
            if self._pool_size:
                busy = np.asarray(self._pool_busy, dtype=np.float64)
                snap["pool"] = {
                    "size": self._pool_size,
                    "busy_mean": float(busy.mean()) if len(busy) else 0.0,
                    "busy_peak": self._pool_busy_peak,
                    "samples": int(len(busy)),
                    "dropped": self._pool_busy_dropped,
                }
        if cache_stats is not None:
            snap["schedule_cache"] = dict(cache_stats)
        return snap
