"""AdamW in pure JAX (no optax) with global-norm clipping.

Moment tensors are stored in float32 regardless of parameter dtype and shard
exactly like their parameters (the optimizer state inherits the parameter
PartitionSpec, giving ZeRO-1 for free on the FSDP axis — see DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    if max_grad_norm:
        grads, norm = clip_by_global_norm(grads, max_grad_norm)
    else:
        norm = global_norm(grads)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), norm
