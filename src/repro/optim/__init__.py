from .adamw import adamw_init, adamw_update
from .schedules import cosine_schedule, linear_warmup

__all__ = ["adamw_init", "adamw_update", "cosine_schedule", "linear_warmup"]
