"""Synthetic LM data pipeline: deterministic, sharded, prefetching.

Generates reproducible token streams with a power-law unigram distribution
plus a deterministic n-gram-ish structure (so a model can actually reduce
loss — pure uniform noise has nothing to learn).  Host-side numpy generation
with a background prefetch thread, sharded per data-parallel rank.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_size: int               # per-host batch
    seed: int = 0
    structure_order: int = 2      # markov order of the synthetic structure
    family: str = "dense"
    vision_tokens: int = 0
    vision_dim: int = 0
    audio_frames_ratio: int = 0
    audio_dim: int = 0


class SyntheticLM:
    """Deterministic synthetic corpus.

    Token t+1 is drawn from a mixture of a global power-law unigram and a
    deterministic permutation of token t (learnable bigram structure).
    """

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks**1.1)
        self.unigram /= self.unigram.sum()
        self.perm = root.permutation(v)          # the learnable structure
        self.mix = 0.7                            # P(follow structure)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * self.num_shards + self.shard
        )
        B, S = cfg.batch_size, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab, size=B, p=self.unigram)
        structure = rng.random((B, S)) < self.mix
        noise = rng.choice(cfg.vocab, size=(B, S), p=self.unigram)
        for t in range(S):
            follow = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(structure[:, t], follow, noise[:, t])
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "vlm":
            out["vision_embeds"] = rng.standard_normal(
                (B, cfg.vision_tokens, cfg.vision_dim)
            ).astype(np.float32)
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (B, S // cfg.audio_frames_ratio, cfg.audio_dim)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (double buffering) over a batch source."""

    def __init__(self, source: SyntheticLM, depth: int = 2, start_step: int = 0):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put(self.source.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self) -> dict:
        return self.q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def data_config_for(model_cfg, *, batch_size: int, seq_len: int, seed: int = 0) -> DataConfig:
    return DataConfig(
        vocab=model_cfg.vocab,
        seq_len=seq_len,
        batch_size=batch_size,
        seed=seed,
        family=model_cfg.family,
        vision_tokens=model_cfg.vision_tokens,
        vision_dim=model_cfg.vision_dim,
        audio_frames_ratio=model_cfg.audio_frames_ratio,
        audio_dim=model_cfg.audio_dim,
    )
