from .pipeline import DataConfig, Prefetcher, SyntheticLM, data_config_for

__all__ = ["DataConfig", "Prefetcher", "SyntheticLM", "data_config_for"]
