"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
        --steps 200 --batch 8 --seq 128

Builds the mesh (or single-device), shards params/optimizer via the
logical-axis rules, seals ONE train-step executable ahead of time (the
Nimble discipline: the loop only submits), and streams the synthetic data
pipeline through it with periodic checkpointing.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data import Prefetcher, SyntheticLM, data_config_for
from repro.checkpoint import save_checkpoint
from repro.distributed.sharding import tree_shardings, use_sharding_ctx
from repro.launch.mesh import make_host_mesh
from repro.models import init_model
from repro.models.transformer import abstract_model
from repro.optim import adamw_init, cosine_schedule
from repro.training.train_lib import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="", help="checkpoint dir (optional)")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--dtype", default="")
    args = ap.parse_args()

    cfg = C.get(args.arch, smoke=args.smoke)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)

    mesh = make_host_mesh(model_axis=args.model_axis) if len(jax.devices()) > 1 else None

    params, axes = init_model(jax.random.key(0), cfg)
    opt_state = adamw_init(params)
    lr = lambda step: cosine_schedule(
        step, peak_lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps
    )
    step_fn = make_train_step(cfg, lr=lr)

    dcfg = data_config_for(cfg, batch_size=args.batch, seq_len=args.seq)
    data = Prefetcher(SyntheticLM(dcfg))

    # --- AoT scheduling: seal the step (lower+compile once) ----------------
    in_shardings = None
    if mesh is not None:
        p_sds, p_axes = abstract_model(cfg)
        p_shard = tree_shardings(p_sds, p_axes, mesh)
        params = jax.device_put(params, p_shard)
        opt_state = jax.device_put(opt_state, adamw_init_shardings(p_shard))

    t0 = time.perf_counter()
    example = next(data)
    with use_sharding_ctx(mesh):
        sealed = jax.jit(step_fn, donate_argnums=(0, 1)).lower(
            params, opt_state, example
        ).compile()
    print(f"sealed train step in {time.perf_counter() - t0:.1f}s "
          f"({cfg.name}: {cfg.param_count/1e6:.1f}M params)")

    losses = []
    t_start = time.perf_counter()
    for step in range(args.steps):
        batch = example if step == 0 else next(data)
        params, opt_state, metrics = sealed(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t_start
            tok_s = (step + 1) * args.batch * args.seq / dt
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"ce {float(metrics['ce']):.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {tok_s:,.0f}")
        if args.ckpt and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, {"params": params}, step=step + 1)
    data.close()

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss: first10={first:.4f} last10={last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params}, step=args.steps)
        print(f"checkpoint -> {args.ckpt}")


def adamw_init_shardings(p_shard):
    from repro.optim.adamw import AdamWState
    from jax.sharding import NamedSharding, PartitionSpec as P

    anyshard = jax.tree_util.tree_leaves(p_shard)[0]
    return AdamWState(
        step=NamedSharding(anyshard.mesh, P()),
        mu=p_shard,
        nu=p_shard,
    )


if __name__ == "__main__":
    main()
