"""Production mesh construction.

Target hardware (spec): TPU v5e-class pods — 256 chips/pod (16×16), 2 pods.
Functions, not module constants, so importing never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model_axis: int = 1):
    """A mesh over whatever devices exist locally (tests / CPU smoke)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def host_device_count() -> int:
    """Addressable local devices — the device axis the worker plane
    (``repro.dispatch.workers.device_topology``) assigns processes over.
    Honors ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for
    multi-device smoke on a CPU-only host."""
    return len(jax.devices())


# Hardware constants for the roofline model (spec-provided, v5e-class).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
CHIPS_PER_POD = 256
