"""HLO-text analysis: collective-byte accounting for the dry-run/roofline.

Standalone (no jax device-state side effects) so tests and tools can import
it without touching XLA_FLAGS.
"""

from __future__ import annotations

import re
from typing import Any

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum operand sizes of every collective op in the HLO, per kind.

    Compiled HLO prints operands as bare ``%names``, so two passes: (1) build
    a symbol table name -> output bytes from every instruction definition;
    (2) for each collective, sum its operands' sizes.  ``-done`` halves of
    async pairs are skipped (payload counted at ``-start``).
    """
    sizes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = DEF_RE.match(line)
        if not m:
            continue
        name, type_str, _op = m.groups()
        sizes[name] = sum(shape_bytes(d, s) for d, s in SHAPE_RE.findall(type_str))

    per_kind: dict[str, int] = {k: 0 for k in COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVES}
    for line in lines:
        m = DEF_RE.match(line)
        if not m:
            continue
        _name, _type_str, op = m.groups()
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base not in per_kind:
            continue
        start = line.index(op + "(") + len(op) + 1
        depth = 1
        out = []
        for ch in line[start:]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            out.append(ch)
        arg_str = "".join(out)
        total = sum(sizes.get(nm, 0) for nm in OPERAND_RE.findall(arg_str))
        per_kind[base] += total
        counts[base] += 1
    return {
        "bytes_per_kind": per_kind,
        "counts": counts,
        "total_bytes": sum(per_kind.values()),
    }
