# Launchers: mesh.py (production meshes), dryrun.py (multi-pod lower+compile
# — NOTE it sets XLA_FLAGS at import; run it as its own process), train.py,
# serve.py.  hlo_analysis.py is side-effect-free and importable anywhere.
