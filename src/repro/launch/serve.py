"""Serving launcher: batched requests through the AoT serving engine.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
        --requests 16 --max-new 12
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.configs as C
from repro.models import init_model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = C.get(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    params, _ = init_model(jax.random.key(0), cfg)

    t0 = time.perf_counter()
    engine = ServingEngine(
        cfg, params, max_slots=args.slots, max_len=args.max_len,
        prompt_buckets=(16, 32),
    )
    print(f"AoT scheduling (seal prefill x{len(engine.prompt_buckets)} + decode): "
          f"{time.perf_counter() - t0:.1f}s")

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 30))).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    done = engine.run_until_drained()
    wall = time.perf_counter() - t0

    st = engine.stats
    lat = [r.t_done - r.t_submit for r in done]
    ttft = [r.t_first - r.t_submit for r in done]
    print(f"served {len(done)} requests in {wall:.2f}s | "
          f"decode steps {st.steps} | {st.decode_tok_per_s:,.0f} tok/s decode")
    print(f"TTFT p50 {np.percentile(ttft, 50)*1e3:.1f}ms p99 {np.percentile(ttft, 99)*1e3:.1f}ms | "
          f"latency p50 {np.percentile(lat, 50)*1e3:.1f}ms")


if __name__ == "__main__":
    main()
