import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (spec deliverable e).

For every (architecture × input shape × mesh) combination this lowers and
compiles the real step function — ``train_step`` for train shapes,
``forward`` for prefill, ``decode_step`` for decode shapes — against
ShapeDtypeStruct inputs on the production mesh (16×16 single-pod and
2×16×16 multi-pod), then records

  * ``compiled.memory_analysis()``  (bytes/device — proves it fits),
  * ``compiled.cost_analysis()``    (HLO FLOPs / bytes for §Roofline),
  * collective bytes parsed from the compiled HLO text, per collective kind.

Results land in ``experiments/dryrun/*.json`` and feed EXPERIMENTS.md
§Dry-run and §Roofline.

NOTE the XLA_FLAGS line above MUST precede any jax import: jax locks the
device count at first backend initialization (which is also why this module
has no ``from __future__`` block — nothing may precede the os.environ line).
"""

import argparse
import dataclasses
import json
import pathlib
import re
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.configs.shapes import INPUT_SHAPES, applicable, input_specs
from repro.distributed.sharding import (
    DEFAULT_RULES,
    LONG_CONTEXT_OVERRIDES,
    tree_shardings,
    use_sharding_ctx,
)
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_model, decode_step, forward
from repro.models.transformer import cache_axes
from repro.optim.adamw import AdamWState
from repro.training.train_lib import make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

from repro.launch.hlo_analysis import (  # noqa: E402 — after XLA_FLAGS
    DEF_RE as _DEF_RE,
    SHAPE_RE as _SHAPE_RE,
    collective_bytes,
    shape_bytes as _shape_bytes,
)


# ---------------------------------------------------------------------------
# step construction
# ---------------------------------------------------------------------------

def _batch_axes(batch: dict) -> dict:
    axes = {}
    for k, v in batch.items():
        if k in ("tokens", "labels"):
            axes[k] = "batch seq"
        elif k == "vision_embeds":
            axes[k] = "batch _ _"
        elif k == "frames":
            axes[k] = "batch _ _"
        else:
            axes[k] = " ".join(["_"] * len(v.shape))
    return axes


def build_case(arch: str, shape_name: str, mesh, *, rules=None, unroll=False,
               overrides=None):
    """Returns (fn, arg_specs, in_shardings, cfg) for jit/lower.

    ``unroll=True`` unrolls layer scans so cost_analysis counts every layer
    (XLA counts while bodies once); used by the roofline pass.  ``overrides``
    is a dict of ModelConfig field replacements (perf experiments).
    """
    cfg = C.get(arch)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sh = INPUT_SHAPES[shape_name]
    kind, specs = input_specs(cfg, shape_name)
    params_sds, params_axes = abstract_model(cfg)
    p_shard = tree_shardings(params_sds, params_axes, mesh, rules)

    if kind == "train":
        cfg_t = dataclasses.replace(cfg, remat=True)
        step = make_train_step(cfg_t, lr=1e-4)
        opt_sds = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds
            ),
            nu=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds
            ),
        )
        f32_shard = tree_shardings(
            opt_sds.mu,
            params_axes,
            mesh,
            rules,
        )
        opt_shard = AdamWState(
            step=NamedSharding(mesh, P()), mu=f32_shard, nu=f32_shard
        )
        batch = specs["batch"]
        b_shard = tree_shardings(batch, _batch_axes(batch), mesh, rules)
        return (
            step,
            (params_sds, opt_sds, batch),
            (p_shard, opt_shard, b_shard),
            cfg_t,
        )

    if kind == "prefill":
        def prefill_fn(params, batch):
            logits, _ = forward(params, batch, cfg)
            return logits

        batch = specs["batch"]
        b_shard = tree_shardings(batch, _batch_axes(batch), mesh, rules)
        return prefill_fn, (params_sds, batch), (p_shard, b_shard), cfg

    # decode
    def serve_step(params, cache, tokens):
        return decode_step(params, cache, tokens, cfg)

    cache_sds = specs["cache"]
    c_shard = tree_shardings(cache_sds, cache_axes(cfg, per_slot=False), mesh, rules)
    tok_shard = tree_shardings(
        {"t": specs["tokens"]}, {"t": "batch seq"}, mesh, rules
    )["t"]
    return (
        serve_step,
        (params_sds, cache_sds, specs["tokens"]),
        (p_shard, c_shard, tok_shard),
        cfg,
    )


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             unroll: bool = False, overrides=None, extra_rules=None,
             donate_argnums: tuple = ()) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(LONG_CONTEXT_OVERRIDES) if shape_name == "long_500k" else None
    if extra_rules:
        rules = {**(rules or {}), **extra_rules}
    t0 = time.perf_counter()
    fn, arg_specs, in_shardings, cfg = build_case(
        arch, shape_name, mesh, rules=rules, unroll=unroll, overrides=overrides
    )

    with use_sharding_ctx(mesh, rules):
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         donate_argnums=donate_argnums)
        lowered = jitted.lower(*arg_specs)
        t_lower = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "collectives": coll,
        "params": C.get(arch).param_count,
        "active_params": C.get(arch).active_param_count,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument(
        "--unroll", action="store_true",
        help="unroll layer scans so cost_analysis counts all layers "
             "(roofline accounting; slower compiles)",
    )
    args = ap.parse_args()

    archs = C.all_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        cfg = C.get(arch)
        for shape in shapes:
            if not applicable(cfg, shape):
                print(f"SKIP  {arch} × {shape} (see DESIGN.md §Shape skips)")
                continue
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                if args.unroll:
                    tag += "_unrolled"
                try:
                    r = run_case(arch, shape, multi_pod=mp, unroll=args.unroll)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((tag, str(e)[:500]))
                    print(f"FAIL  {tag}: {str(e)[:200]}")
                    continue
                out = OUT_DIR / f"{tag}.json"
                out.write_text(json.dumps(r, indent=1))
                print(
                    f"OK    {tag}: compile={r['compile_s']}s "
                    f"flops={r['flops']:.3e} bytes={r['bytes_accessed']:.3e} "
                    f"coll={r['collectives']['total_bytes']:.3e}"
                )
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
