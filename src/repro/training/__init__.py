from .train_lib import cross_entropy, make_loss_fn, make_train_step

__all__ = ["cross_entropy", "make_loss_fn", "make_train_step"]
