"""Training step construction: loss, grads, AdamW update — one jit unit.

The whole step (fwd + bwd + optimizer) is a single function, so the AoT
scheduler seals training exactly like inference (paper §5.3: Nimble supports
training by capturing the full iteration).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import forward
from repro.optim import adamw_update
from repro.optim.adamw import AdamWState


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; label < 0 positions are masked out."""
    V = logits.shape[-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(cfg) -> Callable:
    def loss_fn(params, batch):
        logits, aux = forward(params, batch, cfg)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # image positions carry no next-token loss
            pad = -jnp.ones(
                (labels.shape[0], cfg.vision_tokens), labels.dtype
            )
            labels = jnp.concatenate([pad, labels], axis=1)
        loss = cross_entropy(logits, labels) + aux["aux_loss"]
        return loss, {"ce": loss - aux["aux_loss"], "aux": aux["aux_loss"]}

    return loss_fn


def make_train_step(
    cfg,
    *,
    lr: float | Callable = 3e-4,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
    remat: bool = False,
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg)
    if remat:
        loss_fn = jax.checkpoint(loss_fn)

    def step(params, opt_state: AdamWState, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        lr_val = lr(opt_state.step) if callable(lr) else lr
        new_params, new_state, gnorm = adamw_update(
            grads, opt_state, params,
            lr=lr_val, weight_decay=weight_decay, max_grad_norm=max_grad_norm,
        )
        metrics = {
            "loss": loss,
            "ce": parts["ce"],
            "aux": parts["aux"],
            "grad_norm": gnorm,
            "lr": jnp.asarray(lr_val, jnp.float32),
        }
        return new_params, new_state, metrics

    return step
