from .store import restore_checkpoint, save_checkpoint

__all__ = ["restore_checkpoint", "save_checkpoint"]
