"""Sharding-aware checkpointing: flat-key npz + json manifest.

Save gathers each (possibly sharded) array to host; restore re-places onto
the provided shardings via ``jax.device_put``.  Keys are ``/``-joined pytree
paths so the format is stable across pytree container types, and a manifest
records step/metadata + per-array shape/dtype for integrity checks.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_token(p) for p in path)
        flat[key] = leaf
    return flat


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: str | pathlib.Path, tree: Any, *, step: int = 0,
                    metadata: Optional[dict] = None) -> None:
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "metadata": metadata or {}, "arrays": {}}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8): raw bytes
            arrays[k] = arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
        else:
            arrays[k] = arr
        manifest["arrays"][k] = {"shape": list(arr.shape), "dtype": dtype}
    np.savez(path / "arrays.npz", **arrays)
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))


def restore_checkpoint(path: str | pathlib.Path, like: Any,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; optionally place per-leaf on
    ``shardings`` (same pytree structure)."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as data:
        flat_like = _flatten(like)
        missing = set(flat_like) - set(data.files)
        extra = set(data.files) - set(flat_like)
        if missing or extra:
            raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
        flat_shard = _flatten(shardings) if shardings is not None else {}
        leaves_by_key = {}
        for k, ref in flat_like.items():
            arr = data[k]
            meta = manifest["arrays"][k]
            want = np.dtype(meta["dtype"])  # ml_dtypes registers bfloat16 etc.
            if arr.dtype == np.uint8 and str(arr.dtype) != meta["dtype"]:
                arr = arr.view(want).reshape(tuple(meta["shape"]))
            if tuple(arr.shape) != tuple(np.shape(ref)):
                raise ValueError(f"{k}: shape {arr.shape} != {np.shape(ref)}")
            if k in flat_shard:
                leaves_by_key[k] = jax.device_put(arr, flat_shard[k])
            else:
                leaves_by_key[k] = jax.numpy.asarray(arr)

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = [
        leaves_by_key["/".join(_path_token(p) for p in path)] for path, _ in paths
    ]
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest
