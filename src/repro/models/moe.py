"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Supports the two assigned MoE geometries:
* Arctic  — 128 routed experts top-2 **plus a parallel dense FFN branch**
  (dense-MoE hybrid: output = dense(x) + moe(x));
* DeepSeek-V2 — 2 *shared* experts (always on) + 160 routed experts top-6.

Dispatch: tokens are routed to their top-k experts with a fixed per-expert
capacity C = ceil(N·k/E · capacity_factor).  Token→expert assignment uses the
standard position-in-expert cumsum; overflowing tokens are dropped (their
residual path keeps them alive).  Expert compute is a *grouped* matmul with
the expert dim laid out on the `expert` logical axis — expert-parallel over
the `model` mesh axis, which makes the all_to_all pattern visible to the
dry-run.  Note the stream-scheduling connection: the E experts are exactly
the "parallel branches on different streams" of the paper, realized here as
one grouped kernel (kernels/stream_pack lowers the same pattern in Pallas).

The router's aux load-balancing loss (Shazeer-style) is returned alongside.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, gather_fsdp

from .layers import _act, dense_init


def init_moe(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (m.num_experts, d, m.d_ff_expert), dt),
        "w_up": dense_init(ks[2], (m.num_experts, d, m.d_ff_expert), dt),
        "w_down": dense_init(ks[3], (m.num_experts, m.d_ff_expert, d), dt, in_axis=1),
    }
    a = {
        "router": "fsdp _",
        "w_gate": "expert fsdp mlp",
        "w_up": "expert fsdp mlp",
        "w_down": "expert mlp fsdp",
    }
    if m.num_shared_experts:
        f_sh = m.d_ff_shared * m.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], (d, f_sh), dt),
            "w_up": dense_init(kss[1], (d, f_sh), dt),
            "w_down": dense_init(kss[2], (f_sh, d), dt),
        }
        a["shared"] = {"w_gate": "fsdp mlp", "w_up": "fsdp mlp", "w_down": "mlp fsdp"}
    return p, a


def apply_moe(p, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    N = B * S
    E, K = m.num_experts, m.top_k
    xf = x.reshape(N, D)

    # ---- router --------------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (N, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (mean prob × token fraction per expert)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (N * K)
    aux = m.router_aux_loss * E * jnp.sum(me * ce)

    # ---- capacity-based dispatch (sort-based) -----------------------------
    # Small batches (decode steps, smoke tests) run dropless so that
    # step-by-step decode agrees with the full-sequence forward; at scale the
    # paper-standard capacity factor bounds the grouped-matmul shape.
    if N <= 64:
        cap = N
    else:
        cap = int(max(K, round(N * K / E * m.capacity_factor)))
    flat_e = expert_ids.reshape(-1)                            # (N*K,)
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), K)

    # Sort tokens by expert and derive each token's slot from its rank within
    # the expert's run.  Equivalent ordering to the classic one-hot cumsum
    # (stable sort preserves token order per expert) at a tiny fraction of
    # its cost: the (N·K, E) one-hot prefix-sum dominated the whole model's
    # HLO FLOPs (EXPERIMENTS.md §Perf, deepseek hillclimb).
    order = jnp.argsort(flat_e, stable=True)                   # (N*K,)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                       # (E,)
    pos_in_run = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[sorted_e]
    slot = jnp.zeros_like(flat_e).at[order].set(pos_in_run)    # (N*K,) 0-based
    keep = slot < cap
    slot = jnp.where(keep, slot, cap)                          # overflow -> pad row

    # scatter token features into (E, cap+1, D); row `cap` is the trash slot.
    # NOTE a 2D (expert x capacity) sharding was tried and refuted: GSPMD
    # cannot statically plan the data-dependent scatter as an all-to-all and
    # falls back to replicating the buffers (collective bytes exploded 5x).
    # The production fix is an explicit shard_map ragged-a2a dispatch;
    # recorded as future work in EXPERIMENTS.md §Perf (deepseek it5).
    buf = jnp.zeros((E, cap + 1, D), x.dtype)
    buf = buf.at[flat_e, slot].add(jnp.where(keep[:, None], xf[flat_tok], 0))
    buf = constrain(buf, "expert", "_", "_")
    h = buf[:, :cap]                                           # (E, cap, D)

    # ---- grouped expert FFN (the packed "parallel branches") -------------
    w_gate = gather_fsdp(p["w_gate"], "expert", "fsdp", "mlp", group="moe")
    w_up = gather_fsdp(p["w_up"], "expert", "fsdp", "mlp", group="moe")
    w_down = gather_fsdp(p["w_down"], "expert", "mlp", "fsdp", group="moe")
    g = _act(jnp.einsum("ecd,edf->ecf", h, w_gate), cfg.activation)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    eo = jnp.einsum("ecf,efd->ecd", g * u, w_down)             # (E, cap, D)
    eo = constrain(eo, "expert", "_", "_")

    # ---- combine back ----------------------------------------------------
    gathered = eo[flat_e, jnp.minimum(slot, cap - 1)]          # (N*K, D)
    weight = jnp.where(keep, flat_g, 0.0).astype(x.dtype)
    out = jnp.zeros((N, D), x.dtype).at[flat_tok].add(gathered * weight[:, None])

    # ---- shared experts (DeepSeek) ---------------------------------------
    if "shared" in p:
        sh = p["shared"]
        sg = gather_fsdp(sh["w_gate"], "fsdp", "mlp", group="moe")
        su = gather_fsdp(sh["w_up"], "fsdp", "mlp", group="moe")
        sd = gather_fsdp(sh["w_down"], "mlp", "fsdp", group="moe")
        hs = _act(xf @ sg, cfg.activation) * (xf @ su)
        out = out + hs @ sd

    return out.reshape(B, S, D), aux
