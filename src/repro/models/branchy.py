"""Branchy NAS-style cells (paper's Table 1 regime).

A cell applies ``n_branches`` independent transforms to its input and joins
them — the exact inter-operator-parallel structure of NASNet/DARTS/AmoebaNet
that the paper's multi-stream execution accelerates.  The degree of logical
concurrency of the traced task graph equals ``n_branches`` (checked in
tests), so Table 1's speedup-vs-degree study is reproducible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.branchy_cell import BranchyCellConfig


def init_branchy(key, cfg: BranchyCellConfig):
    keys = jax.random.split(key, cfg.n_cells * cfg.n_branches + 1)
    params = {"stem": jax.random.normal(keys[0], (cfg.width, cfg.width), jnp.float32) * 0.05}
    i = 1
    for c in range(cfg.n_cells):
        for b in range(cfg.n_branches):
            params[f"c{c}b{b}"] = (
                jax.random.normal(keys[i], (cfg.width, cfg.width), jnp.float32)
                * (0.5 / cfg.n_branches)
            )
            i += 1
    return params


def branchy_forward(params, x, cfg: BranchyCellConfig):
    """x: (batch, width)."""
    x = jnp.tanh(x @ params["stem"])
    for c in range(cfg.n_cells):
        branches = [
            jnp.tanh(x @ params[f"c{c}b{b}"]) for b in range(cfg.n_branches)
        ]
        acc = branches[0]
        for br in branches[1:]:
            acc = acc + br
        x = x + acc
    return x


def example_input(cfg: BranchyCellConfig, seed: int = 0):
    return jax.random.normal(jax.random.key(seed), (cfg.batch, cfg.width), jnp.float32)
