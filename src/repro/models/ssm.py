"""Mamba2 (SSD) block — chunked state-space duality algorithm.

TPU adaptation note (DESIGN.md §2): the CUDA Mamba2 kernel is a fused
warp-level scan; the TPU-native formulation is the *chunked SSD* algorithm,
which reformulates the selective scan as (a) an intra-chunk attention-like
batched matmul (MXU-friendly), plus (b) a tiny inter-chunk state scan.  The
sequential dependency collapses from O(S) to O(S/chunk).

State per head: h ∈ R^{head_dim × state_dim};   recurrence
    h_t = exp(dt_t·A) · h_{t-1} + dt_t · x_t ⊗ B_t,      y_t = h_t · C_t + D·x_t
with scalar A per head (Mamba2's SSD restriction), shared B/C across heads
(n_groups=1, GQA-like).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from .layers import dense_init


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim
    return d_inner, n_heads, conv_ch


def init_mamba2(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_ch = ssm_dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        # order: [z (d_inner) | xBC (conv_ch) | dt (H)]
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * s.state_dim + H), dt),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch), dt),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),              # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, d), dt),
    }
    a = {
        "w_in": "fsdp mlp",
        "conv_w": "_ mlp",
        "conv_b": "_",
        "A_log": "_",
        "D": "_",
        "dt_bias": "_",
        "w_out": "mlp fsdp",
    }
    return p, a


def _split_in(z_xbc_dt, cfg):
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    z = z_xbc_dt[..., :d_inner]
    xbc = z_xbc_dt[..., d_inner:d_inner + conv_ch]
    dt_raw = z_xbc_dt[..., d_inner + conv_ch:]
    return z, xbc, dt_raw


def _causal_conv(xbc: jax.Array, p, cfg, conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv, width = conv_width.  xbc: (B,S,C)."""
    w = p["conv_w"].astype(xbc.dtype)                        # (W, C)
    W = w.shape[0]
    if conv_state is not None:
        ctx = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        new_state = ctx[:, -(W - 1):]
    else:
        ctx = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
        new_state = ctx[:, -(W - 1):]
    out = sum(ctx[:, i: i + xbc.shape[1]] * w[i] for i in range(W))
    out = out + p["conv_b"].astype(xbc.dtype)
    return jax.nn.silu(out), new_state


def _ssd_chunked(x, dtv, ldec, Bm, Cm, h0, chunk):
    """Chunked SSD scan.

    x:    (B,S,H,hd)   per-head inputs
    dtv:  (B,S,H)      softplus(dt)
    ldec: (B,S,H)      log decay = dt * A  (negative)
    Bm/Cm:(B,S,ds)     shared input/output maps
    h0:   (B,H,hd,ds)  incoming state
    returns y (B,S,H,hd), h_out (B,H,hd,ds)
    """
    Bsz, S, H, hd = x.shape
    ds = Bm.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    xc = x.reshape(Bsz, nc, chunk, H, hd)
    dtc = dtv.reshape(Bsz, nc, chunk, H)
    lc = ldec.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, ds)
    Cc = Cm.reshape(Bsz, nc, chunk, ds)

    lcum = jnp.cumsum(lc, axis=2)                            # (B,nc,L,H)
    ltot = lcum[:, :, -1]                                    # (B,nc,H)

    # intra-chunk (attention-like, lower-triangular)
    cb = jnp.einsum("bntk,bnsk->bnts", Cc, Bc)               # (B,nc,L,L)
    decay = jnp.exp(
        jnp.clip(lcum[:, :, :, None] - lcum[:, :, None, :], -60.0, 0.0)
    )                                                        # (B,nc,L,L,H) via broadcast
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = cb[..., None] * decay * dtc[:, :, None]              # (B,nc,t,s,H)
    m = jnp.where(mask[None, None, :, :, None], m, 0.0)
    y_intra = jnp.einsum("bntsh,bnshd->bnthd", m, xc)

    # chunk states
    sdecay = jnp.exp(jnp.clip(ltot[:, :, None] - lcum, -60.0, 0.0))  # (B,nc,L,H)
    states = jnp.einsum("bnsh,bnshd,bnsk->bnhdk", sdecay * dtc, xc, Bc)

    # inter-chunk scan (tiny: nc steps)
    def step(h, inp):
        st, lt = inp                                         # (B,H,hd,ds), (B,H)
        h_new = h * jnp.exp(lt)[:, :, None, None] + st
        return h_new, h
    (h_out, h_prevs) = jax.lax.scan(
        step,
        h0,
        (states.transpose(1, 0, 2, 3, 4), ltot.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,hd,ds)

    y_inter = jnp.einsum(
        "bnth,bntk,bnhdk->bnthd",
        jnp.exp(jnp.clip(lcum, -60.0, 0.0)),
        Cc,
        h_prevs,
    )
    y = (y_intra + y_inter).reshape(Bsz, S, H, hd)
    return y, h_out


def mamba2_block(
    p,
    x: jax.Array,                       # (B,S,D)
    cfg,
    *,
    cache: Optional[dict] = None,       # {"h": (B,H,hd,ds), "conv": (B,W-1,C)}
) -> tuple[jax.Array, Optional[dict]]:
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    B_, S, D = x.shape

    zxd = x @ p["w_in"]
    z, xbc, dt_raw = _split_in(zxd, cfg)
    xbc, conv_state = _causal_conv(xbc, p, cfg, cache["conv"] if cache else None)

    x_ssm = xbc[..., :d_inner].reshape(B_, S, H, s.head_dim)
    Bm = xbc[..., d_inner:d_inner + s.state_dim]
    Cm = xbc[..., d_inner + s.state_dim:]

    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                           # (H,)
    ldec = dtv * A                                                     # (B,S,H)

    if cache is None and S > 1:
        chunk = min(s.chunk, S)
        while S % chunk:
            chunk //= 2
        h0 = jnp.zeros((B_, H, s.head_dim, s.state_dim), jnp.float32)
        y, h_out = _ssd_chunked(
            x_ssm.astype(jnp.float32), dtv, ldec,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32), h0, chunk
        )
        new_cache = None
    else:
        # recurrent step(s): S==1 decode
        h0 = cache["h"] if cache else jnp.zeros((B_, H, s.head_dim, s.state_dim), jnp.float32)
        xs = x_ssm.astype(jnp.float32)[:, 0]                 # (B,H,hd)
        h_out = (
            h0 * jnp.exp(ldec[:, 0])[:, :, None, None]
            + jnp.einsum("bh,bhd,bk->bhdk", dtv[:, 0], xs, Bm.astype(jnp.float32)[:, 0])
        )
        y = jnp.einsum("bhdk,bk->bhd", h_out, Cm.astype(jnp.float32)[:, 0])[:, None]
        new_cache = None

    y = y + p["D"][None, None, :, None] * x_ssm.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    if cache is not None or S == 1:
        new_cache = {"h": h_out, "conv": conv_state}
    return out, new_cache
