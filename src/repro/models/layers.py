"""Shared neural-net layers (pure JAX, functional, init/apply pairs).

Every layer is a pair of functions: ``init_*`` returning (params, axes) where
``axes`` is a matching pytree of logical-axis strings (see
distributed/sharding.parse_axes), and an apply function taking params
explicitly.  No framework (flax/haiku) — the parameter tree and its sharding
metadata stay fully visible to the launchers and the dry-run.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, gather_fsdp

Params = dict
Axes = dict


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg, d: Optional[int] = None) -> tuple[Params, Axes]:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        p = {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
        a = {"scale": "_", "bias": "_"}
    else:
        p = {"scale": jnp.zeros((d,), jnp.float32)}  # gemma-style (1+scale)
        a = {"scale": "_"}
    return p, a


def apply_norm(p: Params, x: jax.Array, cfg) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                     # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                   # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# soft capping (gemma2)
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# feed-forward
# ---------------------------------------------------------------------------

def init_ffn(key, cfg, d_ff: Optional[int] = None) -> tuple[Params, Axes]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_gate": dense_init(k1, (d, f), dt),
        "w_up": dense_init(k2, (d, f), dt),
        "w_down": dense_init(k3, (f, d), dt),
    }
    a = {"w_gate": "fsdp mlp", "w_up": "fsdp mlp", "w_down": "mlp fsdp"}
    return p, a


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def apply_ffn(p: Params, x: jax.Array, cfg) -> jax.Array:
    w_gate = gather_fsdp(p["w_gate"], "fsdp", "mlp", group="ffn")
    w_up = gather_fsdp(p["w_up"], "fsdp", "mlp", group="ffn")
    w_down = gather_fsdp(p["w_down"], "mlp", "fsdp", group="ffn")
    h = _act(x @ w_gate, cfg.activation) * (x @ w_up)
    h = constrain(h, "batch", "seq", "mlp")
    return h @ w_down


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embeddings(key, cfg) -> tuple[Params, Axes]:
    dt = _dtype(cfg)
    v, d = cfg.padded_vocab, cfg.d_model
    k1, k2 = jax.random.split(key)
    p: Params = {"tok": embed_init(k1, (v, d), dt)}
    a: Axes = {"tok": "vocab fsdp"}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (d, v), dt)
        a["unembed"] = "fsdp vocab"
    return p, a


def embed_tokens(p: Params, tokens: jax.Array, cfg) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(p: Params, x: jax.Array, cfg) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return softcap(logits, cfg.final_softcap)


# ---------------------------------------------------------------------------
# attention (GQA, sliding window, softcap) with optional KV cache
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> tuple[Params, Axes]:
    d, h = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, nh, h), dt),
        "wk": dense_init(k2, (d, nkv, h), dt),
        "wv": dense_init(k3, (d, nkv, h), dt),
        "wo": dense_init(k4, (nh, h, d), dt, in_axis=0),
    }
    a = {
        "wq": "fsdp heads head_dim",
        "wk": "fsdp kv_heads head_dim",
        "wv": "fsdp kv_heads head_dim",
        "wo": "heads head_dim fsdp",
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((h,), jnp.float32)
        p["k_norm"] = jnp.ones((h,), jnp.float32)
        a["q_norm"] = "_"
        a["k_norm"] = "_"
    return p, a


def _attn_mask(
    q_pos: jax.Array,            # (S,) or (B, S) — per-sequence positions
    kv_pos: jax.Array,           # (T,)
    window,                      # None => full; int or traced int32 otherwise
    kv_len_valid: Optional[jax.Array],   # scalar or (B,)
    causal: bool = True,
) -> jax.Array:
    """(..., q, kv) boolean mask: causal, sliding window, cache length.

    ``q_pos`` may be per-batch (continuous batching: every slot decodes at
    its own offset).  ``window`` may be a traced per-layer value (gemma2's
    local/global alternation runs under one ``lax.scan``).
    """
    qp = q_pos[..., :, None]
    kp = kv_pos[None, :] if q_pos.ndim == 1 else kv_pos[None, None, :]
    if causal:
        m = kp <= qp
    else:
        m = jnp.ones(qp.shape[:-1] + (kv_pos.shape[0],), bool)
    if window is not None:
        m &= kp > qp - window
    if kv_len_valid is not None:
        kv_valid = jnp.asarray(kv_len_valid)
        if kv_valid.ndim == 1 and q_pos.ndim > 1:
            m &= kp < kv_valid[:, None, None]
        else:
            m &= kp < kv_valid
    return m


def attention(
    p: Params,
    x: jax.Array,                     # (B, S, D)
    cfg,
    *,
    positions: jax.Array,             # (B, S)
    layer_window=None,                # None => full causal; int/traced int32
    cache: Optional[dict] = None,     # {"k","v"}: (B, S_max, nkv, hd); "pos"
    causal: bool = True,
    use_flash: bool = False,
    update_cache: bool = True,        # False => deferred append (see below)
) -> tuple[jax.Array, Any]:
    B, S, D = x.shape
    h = cfg.resolved_head_dim
    scale = cfg.attn_logit_scale or (1.0 / math.sqrt(h))

    q = jnp.einsum("bsd,dnh->bsnh", x, gather_fsdp(p["wq"], "fsdp", "heads", "_", group="attn"))
    k = jnp.einsum("bsd,dnh->bsnh", x, gather_fsdp(p["wk"], "fsdp", "kv_heads", "_", group="attn"))
    v = jnp.einsum("bsd,dnh->bsnh", x, gather_fsdp(p["wv"], "fsdp", "kv_heads", "_", group="attn"))
    if cfg.qk_norm:
        q = _rms(q) * p["q_norm"]
        k = _rms(k) * p["k_norm"]
        q, k = q.astype(x.dtype), k.astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "_")
    k = constrain(k, "batch", "seq", "kv_heads", "_")

    kv_valid = None
    if cache is not None and not update_cache:
        # Deferred append: attend against the read-only cache plus the new
        # tokens *without* materializing an updated cache — the caller
        # performs ONE donated dynamic-update-slice for all layers after the
        # layer scan, which XLA can alias in place (the per-layer update
        # inside a scan cannot be elided and costs a full cache copy per
        # step; see EXPERIMENTS.md §Perf, decode hillclimb).
        idx = jnp.broadcast_to(jnp.asarray(cache["pos"]), (B,)).astype(jnp.int32)
        out = _sdpa_deferred(
            q, cache["k"], cache["v"], k, v,
            scale=scale,
            softcap_val=cfg.attn_softcap,
            positions=positions,
            window=layer_window,
            kv_valid=idx,
        )
        y = jnp.einsum(
            "bsnh,nhd->bsd", out, gather_fsdp(p["wo"], "heads", "_", "fsdp", group="attn")
        )
        return y, (k, v)
    if cache is not None:
        # decode / incremental: write new k,v at each slot's own offset
        # (pos is (B,) for continuous batching; scalar broadcasts)
        idx = jnp.broadcast_to(jnp.asarray(cache["pos"]), (B,)).astype(jnp.int32)
        upd = lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
        ck = jax.vmap(upd)(cache["k"], k.astype(cache["k"].dtype), idx)
        cv = jax.vmap(upd)(cache["v"], v.astype(cache["v"].dtype), idx)
        cache = {"k": ck, "v": cv, "pos": cache["pos"] + S}
        k, v = ck, cv
        kv_pos = jnp.arange(k.shape[1])
        q_pos = positions                      # (B, S)
        kv_valid = idx + S
    else:
        kv_pos = positions[0]
        q_pos = positions[0]

    out = _sdpa(
        q, k, v,
        scale=scale,
        softcap_val=cfg.attn_softcap,
        q_pos=q_pos,
        kv_pos=kv_pos,
        window=layer_window,
        kv_valid=kv_valid,
        causal=causal,
    )
    y = jnp.einsum("bsnh,nhd->bsd", out, gather_fsdp(p["wo"], "heads", "_", "fsdp", group="attn"))
    return y, cache


def _rms(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    return xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)


def _sdpa(q, k, v, *, scale, softcap_val, q_pos, kv_pos, window, kv_valid,
          causal=True):
    """Grouped-query scaled dot-product attention, reference path."""
    B, S, NH, H = q.shape
    NKV = k.shape[2]
    G = NH // NKV
    qg = q.reshape(B, S, NKV, G, H)
    logits = jnp.einsum(
        "bsngh,btnh->bngst", qg, k, preferred_element_type=jnp.float32
    )
    logits *= scale
    logits = softcap(logits, softcap_val)
    mask = _attn_mask(q_pos, kv_pos, window, kv_valid, causal)  # (S,T) or (B,S,T)
    if mask.ndim == 2:
        mask = mask[None, None, None]                            # (1,1,1,S,T)
    else:
        mask = mask[:, None, None]                               # (B,1,1,S,T)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", probs.astype(v.dtype), v)
    return out.reshape(B, S, NH, H)


def _sdpa_deferred(q, k_cache, v_cache, k_new, v_new, *, scale, softcap_val,
                   positions, window, kv_valid):
    """Two-part attention for deferred cache append.

    Scores against the (read-only) cache and against the new tokens are
    computed separately and softmaxed jointly — equivalent to attending over
    the updated cache, without writing it.
    q: (B,S,NH,H); k_cache/v_cache: (B,T,NKV,H); k_new/v_new: (B,S,NKV,H);
    kv_valid: (B,) number of valid cache entries (== write offset).
    """
    B, S, NH, H = q.shape
    NKV = k_cache.shape[2]
    G = NH // NKV
    # native-dtype dots with f32 accumulation: converting the cache to f32
    # would materialize a 2x-sized copy of the whole cache per layer (the
    # dominant decode traffic; see EXPERIMENTS.md §Perf decode hillclimb)
    qg = q.reshape(B, S, NKV, G, H)

    # part 1: existing cache
    s1 = jnp.einsum(
        "bsngh,btnh->bngst", qg, k_cache,
        preferred_element_type=jnp.float32,
    ) * scale
    s1 = softcap(s1, softcap_val)
    t = jnp.arange(k_cache.shape[1])
    m1 = t[None, None, :] < kv_valid[:, None, None]              # (B,1,T)
    m1 = m1 & (t[None, None, :] <= positions[..., None])
    if window is not None:
        m1 = m1 & (t[None, None, :] > positions[..., None] - window)
    s1 = jnp.where(m1[:, None, None], s1, -1e30)

    # part 2: the new tokens (causal among themselves)
    s2 = jnp.einsum(
        "bsngh,btnh->bngst", qg, k_new, preferred_element_type=jnp.float32
    ) * scale
    s2 = softcap(s2, softcap_val)
    new_pos = kv_valid[:, None] + jnp.arange(S)[None, :]         # (B,S)
    m2 = new_pos[:, None, :] <= positions[..., None]             # (B,S,S)
    if window is not None:
        m2 = m2 & (new_pos[:, None, :] > positions[..., None] - window)
    s2 = jnp.where(m2[:, None, None], s2, -1e30)

    s = jnp.concatenate([s1, s2], axis=-1)
    probs = jax.nn.softmax(s, axis=-1)
    p1, p2 = probs[..., : k_cache.shape[1]], probs[..., k_cache.shape[1]:]
    out = jnp.einsum("bngst,btnh->bsngh", p1.astype(v_cache.dtype), v_cache)
    out += jnp.einsum("bngst,btnh->bsngh", p2.astype(v_new.dtype), v_new)
    return out.reshape(B, S, NH, H)


def append_kv(cache_k, cache_v, new_k, new_v, pos):
    """One batched cache append for ALL layers (donation-friendly).

    cache_k/v: (L,B,S,nkv,hd); new_k/v: (L,B,S_new,nkv,hd); pos: (B,)."""
    def upd(c, u, i):
        # c: (L,S,nkv,hd) one batch slot across layers
        return jax.lax.dynamic_update_slice(c, u, (0, i, 0, 0))

    ck = jax.vmap(upd, in_axes=(1, 1, 0), out_axes=1)(
        cache_k, new_k.astype(cache_k.dtype), pos
    )
    cv = jax.vmap(upd, in_axes=(1, 1, 0), out_axes=1)(
        cache_v, new_v.astype(cache_v.dtype), pos
    )
    return ck, cv


def cross_attention(p: Params, x: jax.Array, memory: jax.Array, cfg) -> jax.Array:
    """Encoder-decoder cross attention: queries from x, K/V from memory.
    No RoPE on cross keys (positions are heterogeneous across modalities)."""
    B, S, D = x.shape
    T = memory.shape[1]
    h = cfg.resolved_head_dim
    scale = cfg.attn_logit_scale or (1.0 / math.sqrt(h))
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("btd,dnh->btnh", memory, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", memory, p["wv"])
    out = _sdpa(
        q, k, v,
        scale=scale,
        softcap_val=0.0,
        q_pos=jnp.arange(S),
        kv_pos=jnp.arange(T),
        window=None,
        kv_valid=None,
        causal=False,
    )
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
