"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries and keys/values are projected through low-rank *latents*; only the
compressed KV latent (kv_lora_rank) plus a shared RoPE key (qk_rope_head_dim)
are cached at decode time — the architecture's core memory saving.

Two execution paths:
* **prefill/train** — expand K/V from the latent per token (standard form);
* **decode** — *absorbed* form: W_uk is folded into the query so attention
  runs directly against the latent cache (no per-step K expansion).  This is
  DeepSeek's deployment trick and one of this repo's roofline levers.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, gather_fsdp

from .layers import _rms, apply_rope, dense_init


def init_mla(key, cfg):
    m = cfg.mla
    d, nh = cfg.d_model, cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p = {}
    a = {}
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], (d, m.q_lora_rank), dt)
        p["w_uq"] = dense_init(ks[1], (m.q_lora_rank, nh, m.qk_nope_head_dim + m.qk_rope_head_dim), dt)
        a["w_dq"] = "fsdp lora"
        a["w_uq"] = "lora heads head_dim"
    else:
        p["w_q"] = dense_init(ks[0], (d, nh, m.qk_nope_head_dim + m.qk_rope_head_dim), dt)
        a["w_q"] = "fsdp heads head_dim"
    p["w_dkv"] = dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt)
    p["w_uk"] = dense_init(ks[3], (m.kv_lora_rank, nh, m.qk_nope_head_dim), dt)
    p["w_uv"] = dense_init(ks[4], (m.kv_lora_rank, nh, m.v_head_dim), dt)
    p["w_o"] = dense_init(ks[5], (nh, m.v_head_dim, d), dt)
    p["kv_norm_scale"] = jnp.ones((m.kv_lora_rank,), jnp.float32)
    a.update({
        "w_dkv": "fsdp lora",
        "w_uk": "lora heads head_dim",
        "w_uv": "lora heads head_dim",
        "w_o": "heads head_dim fsdp",
        "kv_norm_scale": "_",
    })
    return p, a


def _project_latents(p, x, cfg, positions):
    """Common front: query heads + (latent, shared rope key)."""
    m = cfg.mla
    if "w_dq" in p:
        cq = x @ gather_fsdp(p["w_dq"], "fsdp", "lora", group="attn")
        q = jnp.einsum("bsr,rnh->bsnh", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dnh->bsnh", x, gather_fsdp(p["w_q"], "fsdp", "heads", "_", group="attn"))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ gather_fsdp(p["w_dkv"], "fsdp", "lora", group="attn")                                # (B,S,lora+rope)
    c_kv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = (_rms(c_kv) * p["kv_norm_scale"]).astype(x.dtype)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(
    p,
    x: jax.Array,                       # (B,S,D)
    cfg,
    *,
    positions: jax.Array,
    cache: Optional[dict] = None,       # {"ckv": (B,T,lora), "krope": (B,T,rope), "pos"}
) -> tuple[jax.Array, Optional[dict]]:
    m = cfg.mla
    B, S, _ = x.shape
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope, c_kv, k_rope = _project_latents(p, x, cfg, positions)
    q_nope = constrain(q_nope, "batch", "seq", "heads", "_")

    if cache is None:
        # standard (expanded) form
        k_nope = jnp.einsum("btr,rnh->btnh", c_kv, p["w_uk"])
        v = jnp.einsum("btr,rnh->btnh", c_kv, p["w_uv"])
        logits = (
            jnp.einsum("bsnh,btnh->bnst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
            + jnp.einsum("bsnh,bth->bnst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
        ) * scale
        q_pos = positions[0]
        mask = q_pos[None, :, None] >= jnp.arange(k_nope.shape[1])[None, None, :]
        logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bnst,btnh->bsnh", probs.astype(v.dtype), v)
        y = jnp.einsum("bsnh,nhd->bsd", out, gather_fsdp(p["w_o"], "heads", "_", "fsdp", group="attn"))
        return y, None

    # ---- absorbed decode: attention directly against the latent cache ----
    idx = jnp.broadcast_to(jnp.asarray(cache["pos"]), (B,)).astype(jnp.int32)
    upd = lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0))
    ckv_c = jax.vmap(upd)(cache["ckv"], c_kv.astype(cache["ckv"].dtype), idx)
    krope_c = jax.vmap(upd)(cache["krope"], k_rope.astype(cache["krope"].dtype), idx)
    new_cache = {"ckv": ckv_c, "krope": krope_c, "pos": cache["pos"] + S}

    # fold W_uk into the query: q_lat (B,S,N,lora)
    q_lat = jnp.einsum("bsnh,rnh->bsnr", q_nope, p["w_uk"])
    logits = (
        jnp.einsum("bsnr,btr->bnst", q_lat.astype(jnp.float32), ckv_c.astype(jnp.float32))
        + jnp.einsum("bsnh,bth->bnst", q_rope.astype(jnp.float32), krope_c.astype(jnp.float32))
    ) * scale
    t = jnp.arange(ckv_c.shape[1])
    # per-slot causal + validity: positions is (B,S)
    mask = (
        (t[None, None, :] <= positions[..., None])
        & (t[None, None, :] < (idx + S)[:, None, None])
    )[:, None]                                  # (B,1,S,T)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    # attend in latent space, then expand through W_uv
    ctx_lat = jnp.einsum("bnst,btr->bsnr", probs, ckv_c)
    out = jnp.einsum("bsnr,rnh->bsnh", ctx_lat, p["w_uv"])
    y = jnp.einsum("bsnh,nhd->bsd", out, gather_fsdp(p["w_o"], "heads", "_", "fsdp", group="attn"))
    return y, new_cache
