"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with recurrent gate connections).

Both use exponential gating with the max-stabilizer state m.  The recurrence
is inherently sequential — the paper's multi-stream technique is inapplicable
*inside* the scan (DESIGN.md §Arch-applicability); it still packs the gate
projections, and AoT scheduling applies to the whole block unchanged.

State per head (cache layout):
  mLSTM: C (hd, hd) matrix memory, n (hd) normalizer, m () stabilizer
  sLSTM: c, n, m, h  each (hd,)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense_init


def _heads(cfg):
    return cfg.n_heads, cfg.d_model // cfg.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d = cfg.d_model
    H, hd = _heads(cfg)
    pf = cfg.xlstm.proj_factor
    d_up = int(d * pf)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p = {
        "w_up": dense_init(ks[0], (d, 2 * d_up), dt),         # [u | z]
        "w_qkv": dense_init(ks[1], (d_up, 3 * d_up), dt),
        "w_if": dense_init(ks[2], (d_up, 2 * H), jnp.float32),  # gate logits
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.full((H,), 3.0)]),
        "w_down": dense_init(ks[3], (d_up, d), dt),
    }
    a = {
        "w_up": "fsdp mlp", "w_qkv": "mlp _", "w_if": "mlp _",
        "b_if": "_", "w_down": "mlp fsdp",
    }
    return p, a


def _mlstm_chunked(q, k, v, log_i, log_f, C0, n0, m0, chunk: int):
    """Chunked *parallel* mLSTM (TPU adaptation, cf. Mamba2's SSD):

    mLSTM's gates depend only on the input (no h→gate recurrence), so the
    matrix-memory recurrence unrolls to a decay-weighted attention form
        h_t ∝ Σ_{s≤t} exp(F_t − F_s + i_s − m_t) (q_t·k_s) v_s
    computed per chunk as batched matmuls, with a tiny cross-chunk scan
    carrying (C, n, m).  Exactly equals the recurrent form (tested).

    q,k,v: (B,S,H,hd); log_i/log_f: (B,S,H); C0: (B,H,hd,hd); n0: (B,H,hd);
    m0: (B,H).
    """
    B, S, H, hd = q.shape
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # Global running log-decay / stabilizer (no sequential coupling: the
    # stabilizer is the running max, computable with a parallel prefix).
    F = jnp.cumsum(log_f, axis=1)                            # (B,S,H)
    g = log_i - F
    a = jnp.maximum(jax.lax.cummax(g, axis=1), m0[:, None])  # fold incoming m0
    m = F + a                                                # (B,S,H) per-step stabilizer

    qc = qf.reshape(B, nc, chunk, H, hd)
    kc = kf.reshape(B, nc, chunk, H, hd)
    vc = vf.reshape(B, nc, chunk, H, hd)
    Fc = F.reshape(B, nc, chunk, H)
    mc = m.reshape(B, nc, chunk, H)
    lic = log_i.reshape(B, nc, chunk, H)

    # ---- intra-chunk (all chunks at once; MXU matmuls) -------------------
    qk = jnp.einsum("bnthd,bnshd->bntsh", qc, kc)            # (B,nc,t,s,H)
    w_intra = jnp.exp(
        jnp.clip(
            Fc[:, :, :, None] - Fc[:, :, None, :] + lic[:, :, None, :]
            - mc[:, :, :, None],
            -60.0, 30.0,
        )
    )
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    scores = jnp.where(mask[None, None, :, :, None], qk * w_intra, 0.0)
    num_intra = jnp.einsum("bntsh,bnshd->bnthd", scores, vc)
    den_intra = scores.sum(axis=3)                           # (B,nc,t,H)

    # ---- chunk states (vectorized) ----------------------------------------
    F_end = Fc[:, :, -1]                                     # (B,nc,H)
    ms = mc[:, :, -1]                                        # chunk-end stabilizer
    w_out = jnp.exp(
        jnp.clip(F_end[:, :, None] - Fc + lic - ms[:, :, None], -60.0, 30.0)
    )                                                        # (B,nc,L,H)
    S_c = jnp.einsum("bnsh,bnshk,bnshd->bnhkd", w_out, kc, vc)
    n_c = jnp.einsum("bnsh,bnshk->bnhk", w_out, kc)

    # ---- tiny cross-chunk recurrence (precomputed scalar coefficients) ----
    F_prev = jnp.concatenate([jnp.zeros_like(F_end[:, :1]), F_end[:, :-1]], 1)
    ms_prev = jnp.concatenate([m0[:, None, :], ms[:, :-1]], 1)
    d = jnp.exp(jnp.clip(F_end - F_prev + ms_prev - ms, -60.0, 30.0))  # (B,nc,H)

    def step(carry, inp):
        C, n = carry
        Sn, nn, dn = inp
        C2 = C * dn[:, :, None, None] + Sn
        n2 = n * dn[:, :, None] + nn
        return (C2, n2), (C, n)

    (C_fin, n_fin), (C_prevs, n_prevs) = jax.lax.scan(
        step, (C0, n0),
        (S_c.transpose(1, 0, 2, 3, 4), n_c.transpose(1, 0, 2, 3),
         d.transpose(1, 0, 2)),
    )
    C_prevs = C_prevs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,hd,hd)
    n_prevs = n_prevs.transpose(1, 0, 2, 3)

    # ---- inter-chunk contribution (vectorized) -----------------------------
    # decay from the previous chunk's end (F is a *global* cumsum): F_t - F_prev
    w_state = jnp.exp(
        jnp.clip(Fc - F_prev[:, :, None] + ms_prev[:, :, None] - mc, -60.0, 30.0)
    )                                                        # (B,nc,t,H)
    num_inter = w_state[..., None] * jnp.einsum("bnthk,bnhkd->bnthd", qc, C_prevs)
    den_inter = w_state * jnp.einsum("bnthk,bnhk->bnth", qc, n_prevs)

    den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
    h = (num_intra + num_inter) / den[..., None]
    h = h.reshape(B, S, H, hd)
    m_fin = m[:, -1]
    return h, (C_fin, n_fin, m_fin)


def mlstm_block(p, x, cfg, *, cache: Optional[dict] = None):
    """x: (B,S,D).  Chunked-parallel for sequences; recurrent scan for
    decode (S==1 with cache) — both paths agree (tested)."""
    B, S, D = x.shape
    H, _ = _heads(cfg)
    d_up = p["w_up"].shape[1] // 2
    hd = d_up // H

    u, z = jnp.split(x @ p["w_up"], 2, axis=-1)               # (B,S,d_up)
    qkv = u @ p["w_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, hd) / jnp.sqrt(jnp.asarray(hd, x.dtype))
    k = k.reshape(B, S, H, hd) / jnp.sqrt(jnp.asarray(hd, x.dtype))
    v = v.reshape(B, S, H, hd)
    gates = (u.astype(jnp.float32) @ p["w_if"]) + p["b_if"]   # (B,S,2H)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])

    if cache is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]

    if S > 1:
        # chunked-parallel path (training / prefill)
        chunk = cfg.xlstm.mlstm_chunk
        while S % chunk:
            chunk //= 2
        hs_p, (C, n, m) = _mlstm_chunked(q, k, v, log_i, log_f, C0, n0, m0, chunk)
        h = hs_p.reshape(B, S, d_up).astype(x.dtype)
        out = (h * jax.nn.silu(z)) @ p["w_down"]
        return out, {"C": C, "n": n, "m": m}

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp                              # (B,H,hd)... (B,H)
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)[:, :, None]
        ip = jnp.exp(li - m_new)[:, :, None]
        kf = kt.astype(jnp.float32)
        C = fp[..., None] * C + (ip * kf)[..., None] * vt.astype(jnp.float32)[:, :, None, :]
        n = fp * n + ip * kf
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhk,bhkv->bhv", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (
        q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2),
    )
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_up).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    new_cache = {"C": C, "n": n, "m": m}
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {
        "w_gates": dense_init(ks[0], (d, 4 * d), dt),         # i,f,z,o from x
        "r_gates": dense_init(ks[1], (d, 4 * d), dt),         # recurrent h->gates
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))]
        ),
        "w_out": dense_init(ks[2], (d, d), dt),
    }
    a = {"w_gates": "fsdp mlp", "r_gates": "fsdp mlp", "b_gates": "_", "w_out": "fsdp fsdp"}
    return p, a


def slstm_block(p, x, cfg, *, cache: Optional[dict] = None):
    B, S, D = x.shape
    gx = x @ p["w_gates"]                                     # (B,S,4D)

    if cache is None:
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.ones((B, D), jnp.float32)
        m0 = jnp.zeros((B, D), jnp.float32)
        h0 = jnp.zeros((B, D), x.dtype)
    else:
        c0, n0, m0, h0 = cache["c"], cache["n"], cache["m"], cache["h"]

    r_w = p["r_gates"]
    b = p["b_gates"]

    def step(carry, gxt):
        c, n, m, h = carry
        g = (gxt + h @ r_w).astype(jnp.float32) + b           # (B,4D)
        li, lf, zt, ot = jnp.split(g, 4, axis=-1)
        lf = jax.nn.log_sigmoid(lf)
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        c = fp * c + ip * jnp.tanh(zt)
        n = fp * n + ip
        h_new = (jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)).astype(gxt.dtype)
        return (c, n, m_new, h_new), h_new

    (c, n, m, h_last), hs = jax.lax.scan(step, (c0, n0, m0, h0), gx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)
    out = h @ p["w_out"]
    new_cache = {"c": c, "n": n, "m": m, "h": h_last}
    return out, new_cache
