"""Model assembly for all assigned architecture families.

One functional API across families (dense / moe / vlm / hybrid / ssm / audio):

    params, axes = init_model(key, cfg)
    logits, aux  = forward(params, batch, cfg)                 # full sequence
    cache        = init_cache(cfg, batch_size, max_len)        # decode state
    logits, cache= decode_step(params, cache, tokens, pos, cfg)

Layer stacks are ``lax.scan`` over stacked parameters (bounded HLO size so
the 512-device dry-run compiles quickly); heterogeneous stacks (xLSTM's
sLSTM/mLSTM alternation) unroll since their parameter structures differ.

``abstract_model(cfg)`` returns (ShapeDtypeStruct tree, axes tree) without
allocating — the dry-run path.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from . import layers as L
from .mla import init_mla, mla_attention
from .moe import apply_moe, init_moe
from .ssm import init_mamba2, mamba2_block, ssm_dims
from .xlstm import init_mlstm, init_slstm, mlstm_block, slstm_block

Params = Any


# ---------------------------------------------------------------------------
# per-family layer blocks
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.mla is not None:
        attn_p, attn_a = init_mla(k1, cfg)
    else:
        attn_p, attn_a = L.init_attention(k1, cfg)
    n1p, n1a = L.init_norm(cfg)
    n2p, n2a = L.init_norm(cfg)
    p = {"attn": attn_p, "ln1": n1p, "ln2": n2p}
    a = {"attn": attn_a, "ln1": n1a, "ln2": n2a}
    if cfg.post_attn_norm:
        n3p, n3a = L.init_norm(cfg)
        n4p, n4a = L.init_norm(cfg)
        p["ln_post_attn"], a["ln_post_attn"] = n3p, n3a
        p["ln_post_ffn"], a["ln_post_ffn"] = n4p, n4a
    if cfg.moe is not None:
        moe_p, moe_a = init_moe(k2, cfg)
        p["moe"], a["moe"] = moe_p, moe_a
        if cfg.d_ff:  # arctic: parallel dense residual branch
            ffn_p, ffn_a = L.init_ffn(k3, cfg)
            p["ffn"], a["ffn"] = ffn_p, ffn_a
    else:
        ffn_p, ffn_a = L.init_ffn(k3, cfg)
        p["ffn"], a["ffn"] = ffn_p, ffn_a
    return p, a


def _attn_ffn_block(
    lp, x, cfg, *, positions, window, cache=None, causal=True
):
    """Standard pre-norm transformer block; returns (x, new_cache, aux)."""
    h = L.apply_norm(lp["ln1"], x, cfg)
    if cfg.mla is not None:
        attn_out, new_cache = mla_attention(lp["attn"], h, cfg, positions=positions, cache=cache)
    else:
        attn_out, new_cache = L.attention(
            lp["attn"], h, cfg, positions=positions, layer_window=window, cache=cache
        )
    if cfg.post_attn_norm:
        attn_out = L.apply_norm(lp["ln_post_attn"], attn_out, cfg)
    x = x + attn_out
    x = constrain(x, "batch", "seq", "embed")

    h = L.apply_norm(lp["ln2"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        moe_out, aux = apply_moe(lp["moe"], h, cfg)
        if "ffn" in lp:  # arctic dense residual branch in parallel
            moe_out = moe_out + L.apply_ffn(lp["ffn"], h, cfg)
        ffn_out = moe_out
    else:
        ffn_out = L.apply_ffn(lp["ffn"], h, cfg)
    if cfg.post_attn_norm:
        ffn_out = L.apply_norm(lp["ln_post_ffn"], ffn_out, cfg)
    x = x + ffn_out
    return constrain(x, "batch", "seq", "embed"), new_cache, aux


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(key, cfg):
    keys = jax.random.split(key, 8)
    p: dict = {}
    a: dict = {}
    emb_p, emb_a = L.init_embeddings(keys[0], cfg)
    p["embed"], a["embed"] = emb_p, emb_a
    nf_p, nf_a = L.init_norm(cfg)
    p["final_norm"], a["final_norm"] = nf_p, nf_a

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        lp, la = _stacked_layers(keys[1], cfg, cfg.n_layers, _init_attn_block)
        p["layers"], a["layers"] = lp, la
        if fam == "vlm":
            k1, k2 = jax.random.split(keys[2])
            dt = jnp.dtype(cfg.dtype)
            p["projector"] = {
                "w1": L.dense_init(k1, (cfg.vision_dim, cfg.d_model), dt),
                "w2": L.dense_init(k2, (cfg.d_model, cfg.d_model), dt),
            }
            a["projector"] = {"w1": "_ fsdp", "w2": "fsdp fsdp"}
    elif fam == "hybrid":
        lp, la = _stacked_layers(keys[1], cfg, cfg.n_layers, _init_mamba_block)
        p["layers"], a["layers"] = lp, la
        sp, sa = _init_attn_block(keys[2], cfg)   # the *shared* attention block
        p["shared_attn"], a["shared_attn"] = sp, sa
    elif fam == "ssm":  # xLSTM
        lps, las = [], []
        lkeys = jax.random.split(keys[1], cfg.n_layers)
        for i in range(cfg.n_layers):
            if i in cfg.xlstm.slstm_at:
                bp, ba = _init_xlstm_layer(lkeys[i], cfg, kind="slstm")
            else:
                bp, ba = _init_xlstm_layer(lkeys[i], cfg, kind="mlstm")
            lps.append(bp)
            las.append(ba)
        p["layers"], a["layers"] = lps, las
    elif fam == "audio":
        ep, ea = _stacked_layers(keys[1], cfg, cfg.n_enc_layers, _init_enc_block)
        dp, da = _stacked_layers(keys[2], cfg, cfg.n_layers, _init_dec_block)
        p["encoder"], a["encoder"] = ep, ea
        p["decoder"], a["decoder"] = dp, da
        ne_p, ne_a = L.init_norm(cfg)
        p["enc_final_norm"], a["enc_final_norm"] = ne_p, ne_a
        k1 = keys[3]
        dt = jnp.dtype(cfg.dtype)
        p["frontend_proj"] = {"w": L.dense_init(k1, (cfg.audio_dim, cfg.d_model), dt)}
        a["frontend_proj"] = {"w": "_ fsdp"}
    else:
        raise ValueError(f"unknown family {fam}")
    return p, a


def _stacked_layers(key, cfg, n, init_one):
    keys = jax.random.split(key, max(n, 1))
    ps, as_ = [], []
    for i in range(n):
        bp, ba = init_one(keys[i], cfg)
        ps.append(bp)
        as_.append(ba)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)
    axes = jax.tree_util.tree_map(lambda s: "layers " + s, as_[0])
    return stacked, axes


def _init_mamba_block(key, cfg):
    k1, _ = jax.random.split(key)
    mp, ma = init_mamba2(k1, cfg)
    np_, na = L.init_norm(cfg)
    return {"mamba": mp, "ln": np_}, {"mamba": ma, "ln": na}


def _init_xlstm_layer(key, cfg, *, kind):
    np_, na = L.init_norm(cfg)
    if kind == "slstm":
        bp, ba = init_slstm(key, cfg)
    else:
        bp, ba = init_mlstm(key, cfg)
    return {"ln": np_, "cell": bp}, {"ln": na, "cell": ba}


def _xlstm_kind(cfg, i: int) -> str:
    return "slstm" if i in cfg.xlstm.slstm_at else "mlstm"


def _init_enc_block(key, cfg):
    k1, k2 = jax.random.split(key)
    ap, aa = L.init_attention(k1, cfg)
    fp, fa = L.init_ffn(k2, cfg)
    n1p, n1a = L.init_norm(cfg)
    n2p, n2a = L.init_norm(cfg)
    return (
        {"attn": ap, "ffn": fp, "ln1": n1p, "ln2": n2p},
        {"attn": aa, "ffn": fa, "ln1": n1a, "ln2": n2a},
    )


def _init_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    sp, sa = L.init_attention(k1, cfg)
    cp, ca = L.init_attention(k2, cfg)
    fp, fa = L.init_ffn(k3, cfg)
    norms_p, norms_a = {}, {}
    for nm in ("ln1", "ln2", "ln3"):
        np_, na = L.init_norm(cfg)
        norms_p[nm], norms_a[nm] = np_, na
    return (
        {"self_attn": sp, "cross_attn": cp, "ffn": fp, **norms_p},
        {"self_attn": sa, "cross_attn": ca, "ffn": fa, **norms_a},
    )


def abstract_model(cfg):
    """(ShapeDtypeStruct params, axes) without allocation — dry-run path."""
    axes_box = {}

    def build(key):
        p, a = init_model(key, cfg)
        axes_box["a"] = a
        return p

    shapes = jax.eval_shape(build, jax.random.key(0))
    return shapes, axes_box["a"]


# ---------------------------------------------------------------------------
# forward (full-sequence; training & prefill-style eval)
# ---------------------------------------------------------------------------

def _remat_policy(cfg):
    """Remat policy: 'full' recomputes everything in the backward pass;
    'dots' saves matmul outputs (checkpoint_dots) so the quadratic attention
    scores and FFN GEMMs are not recomputed — trades activation memory for
    the dominant compute term (see EXPERIMENTS.md §Perf, deepseek hillclimb).
    """
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def _window_schedule(cfg) -> Optional[jax.Array]:
    """Per-layer attention window: gemma2 alternates local / global."""
    if not cfg.local_global_pattern or not cfg.sliding_window:
        return None
    idx = jnp.arange(cfg.n_layers)
    is_global = (idx % cfg.local_global_pattern) == (cfg.local_global_pattern - 1)
    return jnp.where(is_global, jnp.int32(2**30), jnp.int32(cfg.sliding_window))


def _embed_input(p, batch, cfg):
    """Token (+modality stub) embedding; returns (x, positions)."""
    tokens = batch["tokens"]
    x = L.embed_tokens(p["embed"], tokens, cfg)
    if cfg.family == "vlm":
        ve = batch["vision_embeds"].astype(x.dtype)          # (B, T_img, vis_d)
        proj = jax.nn.gelu(ve @ p["projector"]["w1"]) @ p["projector"]["w2"]
        x = jnp.concatenate([proj, x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def forward(p, batch, cfg):
    """Full-sequence forward.  Returns (logits, aux) where aux holds router
    losses etc.  batch keys per family: tokens [+ vision_embeds | frames]."""
    fam = cfg.family
    if fam == "audio":
        return _forward_encdec(p, batch, cfg)

    x, positions = _embed_input(p, batch, cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if fam in ("dense", "moe", "vlm"):
        windows = _window_schedule(cfg)
        if windows is None:

            def body(x, lp):
                x, _, aux = _attn_ffn_block(lp, x, cfg, positions=positions, window=None)
                return x, aux

        else:

            def body(x, lp_and_w):
                lp, w = lp_and_w
                x, _, aux = _attn_ffn_block(lp, x, cfg, positions=positions, window=w)
                return x, aux

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
        xs = p["layers"] if windows is None else (p["layers"], windows)
        x, auxs = jax.lax.scan(body, x, xs, unroll=cfg.layer_unroll)
        aux_total = auxs.sum()
    elif fam == "hybrid":
        x, aux_total = _forward_hybrid(p, x, cfg, positions)
    elif fam == "ssm":
        for i, lp in enumerate(p["layers"]):
            x = _xlstm_layer(lp, x, cfg, kind=_xlstm_kind(cfg, i))
    else:
        raise ValueError(fam)

    x = L.apply_norm(p["final_norm"], x, cfg)
    logits = L.unembed(p["embed"], x, cfg)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, {"aux_loss": aux_total}


def _xlstm_layer(lp, x, cfg, *, kind, cache=None, return_cache=False):
    h = L.apply_norm(lp["ln"], x, cfg)
    if kind == "slstm":
        out, new_cache = slstm_block(lp["cell"], h, cfg, cache=cache)
    else:
        out, new_cache = mlstm_block(lp["cell"], h, cfg, cache=cache)
    if return_cache:
        return x + out, new_cache
    return x + out


def _forward_hybrid(p, x, cfg, positions):
    """Zamba2: scan over Mamba2 layers; shared attention block every k."""
    every = cfg.hybrid_attn_every
    idxs = jnp.arange(cfg.n_layers)

    def body(x, inp):
        lp, i = inp
        h = L.apply_norm(lp["ln"], x, cfg)
        out, _ = mamba2_block(lp["mamba"], h, cfg)
        x = x + out

        def with_attn(x):
            y, _, _ = _attn_ffn_block(
                p["shared_attn"], x, cfg, positions=positions, window=None
            )
            return y

        x = jax.lax.cond((i % every) == (every - 1), with_attn, lambda x: x, x)
        return x, jnp.zeros((), jnp.float32)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, (p["layers"], idxs), unroll=cfg.layer_unroll)
    return x, jnp.zeros((), jnp.float32)


def _forward_encdec(p, batch, cfg):
    frames = batch["frames"].astype(jnp.dtype(cfg.dtype))    # (B,T,audio_dim)
    enc_x = frames @ p["frontend_proj"]["w"]
    B, T = enc_x.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def enc_body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg)
        o, _ = L.attention(lp["attn"], h, cfg, positions=enc_pos, causal=False)
        x = x + o
        h = L.apply_norm(lp["ln2"], x, cfg)
        x = x + L.apply_ffn(lp["ffn"], h, cfg)
        return constrain(x, "batch", "seq", "embed"), None

    if cfg.remat:
        enc_body = jax.checkpoint(enc_body, prevent_cse=False, policy=_remat_policy(cfg))
    enc_x, _ = jax.lax.scan(enc_body, enc_x, p["encoder"], unroll=cfg.enc_unroll)
    memory = L.apply_norm(p["enc_final_norm"], enc_x, cfg)

    tokens = batch["tokens"]
    x = L.embed_tokens(p["embed"], tokens, cfg)
    Bd, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (Bd, S))

    def dec_body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg)
        o, _ = L.attention(lp["self_attn"], h, cfg, positions=positions)
        x = x + o
        h = L.apply_norm(lp["ln2"], x, cfg)
        o = L.cross_attention(lp["cross_attn"], h, memory, cfg)
        x = x + o
        h = L.apply_norm(lp["ln3"], x, cfg)
        x = x + L.apply_ffn(lp["ffn"], h, cfg)
        return constrain(x, "batch", "seq", "embed"), None

    if cfg.remat:
        dec_body = jax.checkpoint(dec_body, prevent_cse=False, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(dec_body, x, p["decoder"], unroll=cfg.layer_unroll)
    x = L.apply_norm(p["final_norm"], x, cfg)
    logits = L.unembed(p["embed"], x, cfg)
    return constrain(logits, "batch", "seq", "vocab"), {"aux_loss": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# decode: cache init + single-step
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, memory_len: int = 0,
               per_slot: bool = True):
    """Allocate (or abstractly shape) the per-architecture decode state.

    ``per_slot=True`` gives every batch slot its own write offset
    (continuous batching).  ``per_slot=False`` uses ONE scalar offset for the
    whole batch (synchronized batch decode): the cache append is then a
    single dynamic-update-slice that XLA elides in place under donation —
    the memory-term win of the decode hillclimb (EXPERIMENTS.md §Perf).
    """
    dt = jnp.dtype(cfg.dtype)
    B, Lc = batch_size, cfg.n_layers
    pos0 = jnp.zeros((B,), jnp.int32) if per_slot else jnp.zeros((), jnp.int32)
    fam = cfg.family
    if fam in ("dense", "vlm") or (fam == "moe" and cfg.mla is None):
        h = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((Lc, B, max_len, cfg.n_kv_heads, h), dt),
            "v": jnp.zeros((Lc, B, max_len, cfg.n_kv_heads, h), dt),
            "pos": pos0,
        }
    if fam == "moe":  # MLA latent cache
        m = cfg.mla
        return {
            "ckv": jnp.zeros((Lc, B, max_len, m.kv_lora_rank), dt),
            "krope": jnp.zeros((Lc, B, max_len, m.qk_rope_head_dim), dt),
            "pos": pos0,
        }
    if fam == "hybrid":
        s = cfg.ssm
        d_inner, H, conv_ch = ssm_dims(cfg)
        n_apps = (cfg.n_layers + cfg.hybrid_attn_every - 1) // cfg.hybrid_attn_every
        h = cfg.resolved_head_dim
        return {
            "ssm_h": jnp.zeros((Lc, B, H, s.head_dim, s.state_dim), jnp.float32),
            "conv": jnp.zeros((Lc, B, s.conv_width - 1, conv_ch), dt),
            "attn_k": jnp.zeros((n_apps, B, max_len, cfg.n_kv_heads, h), dt),
            "attn_v": jnp.zeros((n_apps, B, max_len, cfg.n_kv_heads, h), dt),
            "pos": pos0,
        }
    if fam == "ssm":  # xLSTM: per-layer heterogeneous state, python list
        H, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
        pf = cfg.xlstm.proj_factor
        d_up = int(cfg.d_model * pf)
        hd_up = d_up // H
        caches = []
        for i in range(cfg.n_layers):
            if _xlstm_kind(cfg, i) == "slstm":
                caches.append({
                    "c": jnp.zeros((B, cfg.d_model), jnp.float32),
                    "n": jnp.ones((B, cfg.d_model), jnp.float32),
                    "m": jnp.zeros((B, cfg.d_model), jnp.float32),
                    "h": jnp.zeros((B, cfg.d_model), dt),
                })
            else:
                caches.append({
                    "C": jnp.zeros((B, H, hd_up, hd_up), jnp.float32),
                    "n": jnp.zeros((B, H, hd_up), jnp.float32),
                    "m": jnp.full((B, H), -1e30, jnp.float32),
                })
        return {"layers": caches, "pos": pos0}
    if fam == "audio":
        h = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((Lc, B, max_len, cfg.n_kv_heads, h), dt),
            "v": jnp.zeros((Lc, B, max_len, cfg.n_kv_heads, h), dt),
            "memory": jnp.zeros((B, memory_len, cfg.d_model), dt),
            "pos": pos0,
        }
    raise ValueError(fam)


def cache_axes(cfg, per_slot: bool = True):
    """Logical-axis strings matching :func:`init_cache`'s structure (for the
    dry-run's NamedShardings; see distributed/sharding.parse_axes)."""
    fam = cfg.family
    pos_ax = "batch" if per_slot else ""
    if fam in ("dense", "vlm") or (fam == "moe" and cfg.mla is None):
        return {
            "k": "layers batch kv_seq kv_heads _",
            "v": "layers batch kv_seq kv_heads _",
            "pos": pos_ax,
        }
    if fam == "moe":
        return {
            "ckv": "layers batch kv_seq _",
            "krope": "layers batch kv_seq _",
            "pos": pos_ax,
        }
    if fam == "hybrid":
        return {
            "ssm_h": "layers batch heads _ _",
            "conv": "layers batch _ mlp",
            "attn_k": "_ batch kv_seq kv_heads _",
            "attn_v": "_ batch kv_seq kv_heads _",
            "pos": pos_ax,
        }
    if fam == "ssm":
        per = []
        for i in range(cfg.n_layers):
            if _xlstm_kind(cfg, i) == "slstm":
                per.append({"c": "batch _", "n": "batch _", "m": "batch _", "h": "batch _"})
            else:
                per.append({"C": "batch heads _ _", "n": "batch heads _", "m": "batch heads"})
        return {"layers": per, "pos": pos_ax}
    if fam == "audio":
        return {
            "k": "layers batch kv_seq kv_heads _",
            "v": "layers batch kv_seq kv_heads _",
            "memory": "batch _ _",
            "pos": pos_ax,
        }
    raise ValueError(fam)


def decode_step(p, cache, tokens, cfg):
    """One decode step: tokens (B, S_new) → (logits (B,S_new,V), new cache).

    ``cache["pos"]`` is per-slot (B,) — every batch slot decodes at its own
    offset (continuous batching; see serving/).  S_new > 1 runs a cached
    chunked prefill (used by the serving engine's prompt buckets).
    """
    fam = cfg.family
    pos_raw = jnp.asarray(cache["pos"])
    synced = pos_raw.ndim == 0                   # scalar: synchronized decode
    pos = jnp.broadcast_to(pos_raw, (tokens.shape[0],)).astype(jnp.int32)
    B, S_new = tokens.shape
    x = L.embed_tokens(p["embed"], tokens, cfg)
    # per-slot offsets; multi-token chunks get consecutive positions
    positions = pos[:, None] + jnp.arange(S_new, dtype=jnp.int32)[None, :]

    if fam in ("dense", "vlm", "moe"):
        windows = _window_schedule(cfg)
        use_mla = cfg.mla is not None

        def body(x, inp):
            if windows is None:
                lp, (ck, cv) = inp
                w = None
            else:
                lp, (ck, cv), w = inp
            if use_mla:
                lcache = {"ckv": ck, "krope": cv, "pos": pos}
                h = L.apply_norm(lp["ln1"], x, cfg)
                attn_out, nc = mla_attention(lp["attn"], h, cfg, positions=positions, cache=lcache)
                x = x + attn_out
                new_k, new_v = nc["ckv"], nc["krope"]
            else:
                lcache = {"k": ck, "v": cv, "pos": pos}
                h = L.apply_norm(lp["ln1"], x, cfg)
                # deferred append: read-only cache here; ONE donated update
                # for all layers after the scan (see layers._sdpa_deferred)
                attn_out, (new_k, new_v) = L.attention(
                    lp["attn"], h, cfg, positions=positions, layer_window=w,
                    cache=lcache, update_cache=False,
                )
                if cfg.post_attn_norm:
                    attn_out = L.apply_norm(lp["ln_post_attn"], attn_out, cfg)
                x = x + attn_out
            h = L.apply_norm(lp["ln2"], x, cfg)
            if cfg.moe is not None:
                ffn_out, _ = apply_moe(lp["moe"], h, cfg)
                if "ffn" in lp:
                    ffn_out = ffn_out + L.apply_ffn(lp["ffn"], h, cfg)
            else:
                ffn_out = L.apply_ffn(lp["ffn"], h, cfg)
            if cfg.post_attn_norm:
                ffn_out = L.apply_norm(lp["ln_post_ffn"], ffn_out, cfg)
            return x + ffn_out, (new_k, new_v)

        if use_mla:
            kv = (cache["ckv"], cache["krope"])
        else:
            kv = (cache["k"], cache["v"])
        if windows is None:
            x, new_kv = jax.lax.scan(body, x, (p["layers"], kv), unroll=cfg.layer_unroll)
        else:
            x, new_kv = jax.lax.scan(body, x, (p["layers"], kv, windows), unroll=cfg.layer_unroll)
        if use_mla:
            new_cache = {"ckv": new_kv[0], "krope": new_kv[1], "pos": cache["pos"] + S_new}
        else:
            if synced:
                # ONE donated-aliasable update for all layers and slots
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], new_kv[0].astype(cache["k"].dtype),
                    (0, 0, pos_raw, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], new_kv[1].astype(cache["v"].dtype),
                    (0, 0, pos_raw, 0, 0))
            else:
                ck, cv = L.append_kv(cache["k"], cache["v"], new_kv[0], new_kv[1], pos)
            new_cache = {"k": ck, "v": cv, "pos": cache["pos"] + S_new}
    elif fam == "hybrid":
        x, new_cache = _decode_hybrid(p, cache, x, cfg, positions)
    elif fam == "ssm":
        new_layers = []
        for i, lp in enumerate(p["layers"]):
            x, nc = _xlstm_layer(
                lp, x, cfg, kind=_xlstm_kind(cfg, i),
                cache=cache["layers"][i], return_cache=True,
            )
            new_layers.append(nc)
        new_cache = {"layers": new_layers, "pos": pos + 1}
    elif fam == "audio":
        x, new_cache = _decode_encdec(p, cache, x, cfg, positions)
    else:
        raise ValueError(fam)

    x = L.apply_norm(p["final_norm"], x, cfg)
    logits = L.unembed(p["embed"], x, cfg)
    return logits, new_cache


def _decode_hybrid(p, cache, x, cfg, positions):
    every = cfg.hybrid_attn_every
    pos = cache["pos"]
    idxs = jnp.arange(cfg.n_layers)

    def body(carry, inp):
        x, ak, av = carry
        lp, (hs, conv), i = inp
        h = L.apply_norm(lp["ln"], x, cfg)
        out, nc = mamba2_block(lp["mamba"], h, cfg, cache={"h": hs, "conv": conv})
        x = x + out

        app = i // every

        def with_attn(operand):
            x, ak, av = operand
            ck = jax.lax.dynamic_index_in_dim(ak, app, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(av, app, 0, keepdims=False)
            h = L.apply_norm(p["shared_attn"]["ln1"], x, cfg)
            attn_out, nkv = L.attention(
                p["shared_attn"]["attn"], h, cfg, positions=positions,
                cache={"k": ck, "v": cv, "pos": pos},
            )
            x = x + attn_out
            h = L.apply_norm(p["shared_attn"]["ln2"], x, cfg)
            x = x + L.apply_ffn(p["shared_attn"]["ffn"], h, cfg)
            ak = jax.lax.dynamic_update_index_in_dim(ak, nkv["k"], app, 0)
            av = jax.lax.dynamic_update_index_in_dim(av, nkv["v"], app, 0)
            return x, ak, av

        x, ak, av = jax.lax.cond(
            (i % every) == (every - 1), with_attn, lambda o: o, (x, ak, av)
        )
        return (x, ak, av), (nc["h"], nc["conv"])

    (x, ak, av), (hs, conv) = jax.lax.scan(
        body, (x, cache["attn_k"], cache["attn_v"]),
        (p["layers"], (cache["ssm_h"], cache["conv"]), idxs),
    )
    new_cache = {
        "ssm_h": hs, "conv": conv, "attn_k": ak, "attn_v": av, "pos": pos + 1
    }
    return x, new_cache


def _decode_encdec(p, cache, x, cfg, positions):
    pos = cache["pos"]
    memory = cache["memory"]

    def body(x, inp):
        lp, (ck, cv) = inp
        h = L.apply_norm(lp["ln1"], x, cfg)
        o, nc = L.attention(
            lp["self_attn"], h, cfg, positions=positions,
            cache={"k": ck, "v": cv, "pos": pos},
        )
        x = x + o
        h = L.apply_norm(lp["ln2"], x, cfg)
        x = x + L.cross_attention(lp["cross_attn"], h, memory, cfg)
        h = L.apply_norm(lp["ln3"], x, cfg)
        x = x + L.apply_ffn(lp["ffn"], h, cfg)
        return x, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(body, x, (p["decoder"], (cache["k"], cache["v"])), unroll=cfg.layer_unroll)
    new_cache = {"k": nk, "v": nv, "memory": memory, "pos": pos + 1}
    return x, new_cache


def encode_memory(p, frames, cfg):
    """Run the encoder once (enc-dec prefill) and return memory."""
    enc_x = frames.astype(jnp.dtype(cfg.dtype)) @ p["frontend_proj"]["w"]
    B, T = enc_x.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def enc_body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg)
        o, _ = L.attention(lp["attn"], h, cfg, positions=enc_pos, causal=False)
        x = x + o
        h = L.apply_norm(lp["ln2"], x, cfg)
        x = x + L.apply_ffn(lp["ffn"], h, cfg)
        return x, None

    enc_x, _ = jax.lax.scan(enc_body, enc_x, p["encoder"], unroll=cfg.enc_unroll)
    return L.apply_norm(p["enc_final_norm"], enc_x, cfg)
