from .transformer import (
    abstract_model,
    decode_step,
    encode_memory,
    forward,
    init_cache,
    init_model,
)

__all__ = [
    "abstract_model", "decode_step", "encode_memory",
    "forward", "init_cache", "init_model",
]
