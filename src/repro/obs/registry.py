"""Pull-based metrics registry: one collector protocol over every series.

The dispatch plane already *has* the numbers — ``DispatchMetrics``
snapshots, ``ScheduleCache.snapshot()``, ``FairnessPolicy.snapshot()``,
the arbiter's wakeup/grant counters — but each lives behind its own
ad-hoc dict shape, so "what is the system doing right now" means knowing
four APIs.  :class:`MetricsRegistry` unifies them behind one **pull**
model: nothing is pushed at record time; each registered collector is
invoked at :meth:`MetricsRegistry.collect` time and returns typed
:class:`Sample` values (counter / gauge / summary / histogram), which the
registry exposes as JSON (:meth:`MetricsRegistry.to_json`) or
Prometheus text exposition (:meth:`MetricsRegistry.to_prometheus`).

Three layers:

* **Typed instruments** — :class:`Counter`, :class:`Gauge`,
  :class:`Histogram`: thread-safe primitives for new code that wants to
  record directly into the registry model.
* **Adapters** — :func:`register_dispatch` / :func:`register_cache`
  translate the existing snapshot dicts into samples at collect time, so
  a dispatcher + cache stack is fully exposed without changing how it
  records: the ``dispatcher``, ``fairness``, ``arbiter``, ``pool``, and
  ``schedule_cache`` groups all come out of one ``collect()``.
* **Escape hatch** — :meth:`MetricsRegistry.register` takes any callable
  returning samples (or any object with a ``samples()`` method), so new
  subsystems join the plane without touching this module.

Everything is stdlib-only and duck-typed against the dispatch layer (no
imports from ``repro.dispatch``), so ``repro.dispatch`` may depend on
``repro.obs`` without a cycle.
"""

from __future__ import annotations

import bisect
import dataclasses
import json
import re
import threading
from collections import OrderedDict
from typing import Any, Callable, Iterable, Optional, Union

COUNTER = "counter"      #: monotonically increasing total
GAUGE = "gauge"          #: point-in-time value
SUMMARY = "summary"      #: precomputed quantiles dict (count/mean/p50/…)
HISTOGRAM = "histogram"  #: cumulative bucket counts + sum + count


@dataclasses.dataclass(frozen=True)
class Sample:
    """One exposed metric sample.

    ``kind`` is one of :data:`COUNTER` / :data:`GAUGE` / :data:`SUMMARY` /
    :data:`HISTOGRAM`.  ``value`` is a number for counters and gauges, a
    dict of precomputed aggregates for summaries (the metrics layer's
    ``summary_ms`` shape: count/mean/p50/p90/p95/p99/max, optionally
    ``dropped``), and for histograms a dict with ``buckets`` (upper-bound
    → cumulative count), ``sum`` and ``count``.  ``labels`` is a sorted
    tuple of ``(key, value)`` pairs."""

    name: str
    kind: str
    value: Any
    labels: tuple = ()

    def as_dict(self) -> dict:
        """Plain-dict view (JSON exposition unit)."""
        out: dict[str, Any] = {"name": self.name, "kind": self.kind,
                               "value": self.value}
        if self.labels:
            out["labels"] = dict(self.labels)
        return out


class Counter:
    """Thread-safe monotonically increasing counter instrument."""

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._v = 0.0
        self._mu = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0 — counters only go up)."""
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._mu:
            self._v += n

    @property
    def value(self) -> float:
        """Current total."""
        with self._mu:
            return self._v

    def samples(self) -> list[Sample]:
        """This counter as a one-sample collector."""
        return [Sample(self.name, COUNTER, self.value)]


class Gauge:
    """Thread-safe point-in-time gauge; either set explicitly or backed
    by a callable evaluated at collect time (pull semantics)."""

    def __init__(
        self, name: str, help: str = "",
        fn: Optional[Callable[[], float]] = None,
    ) -> None:
        self.name = name
        self.help = help
        self._fn = fn
        self._v = 0.0
        self._mu = threading.Lock()

    def set(self, v: float) -> None:
        """Set the gauge (ignored at collect time if a ``fn`` backs it)."""
        with self._mu:
            self._v = float(v)

    @property
    def value(self) -> float:
        """Current value (evaluates the backing callable, if any)."""
        if self._fn is not None:
            return float(self._fn())
        with self._mu:
            return self._v

    def samples(self) -> list[Sample]:
        """This gauge as a one-sample collector."""
        return [Sample(self.name, GAUGE, self.value)]


class Histogram:
    """Thread-safe cumulative-bucket histogram instrument.

    ``buckets`` are the upper bounds (sorted ascending; a ``+Inf`` bucket
    is implicit).  ``observe`` is O(log buckets)."""

    DEFAULT_BUCKETS = (
        0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
        0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
    )

    def __init__(
        self, name: str, help: str = "",
        buckets: Iterable[float] = DEFAULT_BUCKETS,
    ) -> None:
        self.name = name
        self.help = help
        self._bounds = sorted(float(b) for b in buckets)
        if not self._bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._counts = [0] * (len(self._bounds) + 1)   # +Inf at the end
        self._sum = 0.0
        self._count = 0
        self._mu = threading.Lock()

    def observe(self, v: float) -> None:
        """Record one observation into its bucket."""
        i = bisect.bisect_left(self._bounds, v)
        with self._mu:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def samples(self) -> list[Sample]:
        """This histogram as a one-sample collector (cumulative buckets)."""
        with self._mu:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum, buckets = 0, OrderedDict()
        for bound, c in zip(self._bounds, counts):
            cum += c
            buckets[str(bound)] = cum
        buckets["+Inf"] = total
        return [Sample(
            self.name, HISTOGRAM,
            {"buckets": buckets, "sum": s, "count": total},
        )]


CollectorLike = Union[Callable[[], Iterable[Sample]], Any]


class MetricsRegistry:
    """Named groups of pull collectors with JSON + Prometheus exposition.

    ``register(group, collector)`` accepts a callable returning samples,
    an object with a ``samples()`` method (the typed instruments), or an
    iterable of either.  ``collect()`` pulls every group once and returns
    ``{group: [sample dicts]}`` — one coherent snapshot across
    dispatcher, fairness, arbiter, and cache series.  A collector that
    raises contributes an ``up == 0`` gauge for its group instead of
    poisoning the whole scrape (the Prometheus convention).  Thread-safe.
    """

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._groups: "OrderedDict[str, list]" = OrderedDict()

    def register(self, group: str, collector: CollectorLike) -> None:
        """Add ``collector`` under ``group`` (multiple collectors may
        share a group; their samples concatenate)."""
        with self._mu:
            self._groups.setdefault(group, []).append(collector)

    def unregister(self, group: str) -> None:
        """Drop every collector registered under ``group``."""
        with self._mu:
            self._groups.pop(group, None)

    @property
    def groups(self) -> tuple:
        """Registered group names, in registration order."""
        with self._mu:
            return tuple(self._groups)

    @staticmethod
    def _pull(collector: CollectorLike) -> list[Sample]:
        if hasattr(collector, "samples"):
            return list(collector.samples())
        return list(collector())

    def collect(self) -> dict:
        """Pull every collector once: ``{group: [sample dicts]}``."""
        with self._mu:
            groups = {g: list(cs) for g, cs in self._groups.items()}
        out: dict[str, list] = {}
        for group, collectors in groups.items():
            samples: list[dict] = []
            for c in collectors:
                try:
                    samples.extend(s.as_dict() for s in self._pull(c))
                except Exception as exc:  # noqa: BLE001 - scrape isolation
                    samples.append(Sample(
                        "up", GAUGE, 0.0, (("error", repr(exc)),)
                    ).as_dict())
            out[group] = samples
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        """One :meth:`collect` snapshot as a JSON document."""
        return json.dumps(self.collect(), indent=indent, default=str)

    def to_prometheus(self) -> str:
        """One :meth:`collect` snapshot in Prometheus text exposition
        format (version 0.0.4): ``repro_<group>_<name>`` metric names,
        ``# TYPE`` headers, quantile-labelled summaries, cumulative
        ``_bucket`` histogram series."""
        lines: list[str] = []
        for group, samples in self.collect().items():
            for s in samples:
                name = _prom_name(f"repro_{group}_{s['name']}")
                labels = s.get("labels", {})
                kind = s["kind"]
                if kind in (COUNTER, GAUGE):
                    lines.append(f"# TYPE {name} {kind}")
                    lines.append(f"{name}{_prom_labels(labels)} "
                                 f"{_prom_num(s['value'])}")
                elif kind == SUMMARY:
                    lines.extend(_prom_summary(name, s["value"], labels))
                elif kind == HISTOGRAM:
                    lines.extend(_prom_histogram(name, s["value"], labels))
        return "\n".join(lines) + "\n"


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _PROM_BAD.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _prom_labels(labels: dict, extra: tuple = ()) -> str:
    items = list(labels.items()) + list(extra)
    if not items:
        return ""
    body = ",".join(
        f'{_prom_name(str(k))}="{_prom_escape(v)}"' for k, v in items
    )
    return "{" + body + "}"


def _prom_escape(v: Any) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _prom_num(v: Any) -> str:
    try:
        f = float(v)
    except (TypeError, ValueError):
        return "0"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


_QUANTILES = (("p50", "0.5"), ("p90", "0.9"), ("p95", "0.95"), ("p99", "0.99"))


def _prom_summary(name: str, value: dict, labels: dict) -> list[str]:
    lines = [f"# TYPE {name} summary"]
    for key, q in _QUANTILES:
        if key in value:
            lines.append(
                f"{name}{_prom_labels(labels, (('quantile', q),))} "
                f"{_prom_num(value[key])}"
            )
    if "count" in value:
        lines.append(f"{name}_count{_prom_labels(labels)} "
                     f"{_prom_num(value['count'])}")
    for aux in ("mean", "max", "dropped"):
        if aux in value:
            lines.append(f"{name}_{aux}{_prom_labels(labels)} "
                         f"{_prom_num(value[aux])}")
    return lines


def _prom_histogram(name: str, value: dict, labels: dict) -> list[str]:
    lines = [f"# TYPE {name} histogram"]
    for bound, cum in value.get("buckets", {}).items():
        lines.append(
            f"{name}_bucket{_prom_labels(labels, (('le', bound),))} "
            f"{_prom_num(cum)}"
        )
    lines.append(f"{name}_sum{_prom_labels(labels)} "
                 f"{_prom_num(value.get('sum', 0.0))}")
    lines.append(f"{name}_count{_prom_labels(labels)} "
                 f"{_prom_num(value.get('count', 0))}")
    return lines


# -- adapters over the dispatch layer's snapshot dicts ---------------------

def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _is_summary(v: Any) -> bool:
    return isinstance(v, dict) and "count" in v and (
        "p50" in v or "mean" in v
    )


def samples_from_dict(
    d: dict, *, prefix: str = "", labels: tuple = (), counters: tuple = (),
) -> list[Sample]:
    """Generic snapshot-dict → samples translation.

    Numeric leaves become gauges (or counters, when their dotted path is
    listed in ``counters``); ``summary_ms``-shaped dicts become summaries;
    a dict of ``str → number`` (e.g. per-lane ``served_steps``) becomes
    one labelled sample per key; other nested dicts recurse with a dotted
    prefix.  Non-numeric leaves (policy names, flags) are skipped —
    exposition formats carry numbers, not strings."""
    out: list[Sample] = []
    for key, v in d.items():
        name = f"{prefix}{key}"
        kind = COUNTER if name in counters else GAUGE
        if _is_num(v):
            out.append(Sample(name, kind, v, labels))
        elif isinstance(v, bool):
            out.append(Sample(name, GAUGE, float(v), labels))
        elif _is_summary(v):
            out.append(Sample(name, SUMMARY, dict(v), labels))
        elif isinstance(v, dict):
            if v and all(_is_num(x) for x in v.values()):
                for sub, x in v.items():
                    out.append(Sample(
                        name, kind, x, labels + (("key", str(sub)),)
                    ))
            else:
                out.extend(samples_from_dict(
                    v, prefix=f"{name}.", labels=labels, counters=counters,
                ))
    return out


_DISPATCH_COUNTERS = (
    "requests_done", "tokens_out", "rejected", "grants",
)
_ARBITER_COUNTERS = (
    "grants", "timed_grants", "timed_wakeups", "notify_wakeups",
)


def register_dispatch(registry: MetricsRegistry, dispatcher: Any) -> None:
    """Expose a (sync or async) dispatcher through ``registry``.

    Registers pull collectors over ``dispatcher.snapshot()`` split into
    the groups operators actually dashboard separately: ``dispatcher``
    (request/latency/throughput/grant series, per-engine breakdown with
    ``lane`` labels), ``fairness`` (the policy's own snapshot),
    ``arbiter`` (wakeup/grant counters + parking state, async only), and
    ``pool`` (occupancy, pool mode only).  Everything is pulled at
    collect time — one ``snapshot()`` call per scrape."""

    def _snap() -> dict:
        return dispatcher.snapshot()

    def dispatch_samples() -> list[Sample]:
        snap = _snap()
        out = samples_from_dict(
            {k: v for k, v in snap.items()
             if k not in ("fairness", "engines", "async", "schedule_cache",
                          "models", "pool")},
            counters=_DISPATCH_COUNTERS,
        )
        for lane, rec in snap.get("engines", {}).items():
            out.extend(samples_from_dict(
                rec, prefix="engine.", labels=(("lane", lane),),
                counters=("engine.steps", "engine.tokens"),
            ))
        return out

    def fairness_samples() -> list[Sample]:
        return samples_from_dict(_snap().get("fairness", {}))

    def arbiter_samples() -> list[Sample]:
        snap = _snap()
        arb = (snap.get("async") or {}).get("arbiter") or {}
        out = samples_from_dict(arb, counters=_ARBITER_COUNTERS)
        async_snap = snap.get("async") or {}
        for key in ("steppers", "futures_pending", "builds_on_thread"):
            if key in async_snap:
                out.append(Sample(key, GAUGE, async_snap[key]))
        return out

    def pool_samples() -> list[Sample]:
        return samples_from_dict(_snap().get("pool", {}))

    registry.register("dispatcher", dispatch_samples)
    registry.register("fairness", fairness_samples)
    if hasattr(dispatcher, "builds_by_stepper"):      # async front door
        registry.register("arbiter", arbiter_samples)
    registry.register("pool", pool_samples)


_CACHE_COUNTERS = (
    "hits", "misses", "evictions", "bytes_evicted", "builds",
)


def register_cache(
    registry: MetricsRegistry, cache: Any, *, group: str = "schedule_cache",
) -> None:
    """Expose a ``ScheduleCache`` through ``registry`` under ``group``:
    hit/miss/eviction/build counters, build-time totals, entry count and
    resident arena bytes against the configured budget — pulled from
    ``cache.snapshot()`` at collect time."""

    def cache_samples() -> list[Sample]:
        snap = cache.snapshot()
        out = samples_from_dict(
            {k: v for k, v in snap.items() if k not in ("entries", "stats")},
        )
        out.extend(samples_from_dict(
            snap.get("stats", {}), counters=_CACHE_COUNTERS,
        ))
        return out

    registry.register(group, cache_samples)


def register_worker_plane(
    registry: MetricsRegistry, plane: Any, *, group: str = "workers",
) -> None:
    """Expose a ``WorkerPlane`` through ``registry`` under ``group``: one
    ``up`` gauge plus per-worker serving/dead/abandoned state, restart
    counts, heartbeat age, lane census, and the worker-reported
    step/token counters (shipped back with each heartbeat), labelled by
    worker index and device — pulled from ``plane.snapshot()`` at
    collect time."""

    def plane_samples() -> list[Sample]:
        snap = plane.snapshot()
        out = [
            Sample("n_workers", GAUGE, snap.get("n_workers", 0)),
        ]
        for rec in snap.get("workers", ()):
            labels = (
                ("worker", str(rec.get("index", ""))),
                ("device", str(rec.get("device", ""))),
            )
            out.append(Sample(
                "up", GAUGE,
                1.0 if rec.get("status") == "serving" else 0.0, labels,
            ))
            out.append(Sample(
                "restarts", COUNTER, rec.get("restarts", 0), labels,
            ))
            out.append(Sample(
                "lanes", GAUGE, len(rec.get("lanes", ())), labels,
            ))
            hb = rec.get("heartbeat_age_s")
            if _is_num(hb):
                out.append(Sample("heartbeat_age_s", GAUGE, hb, labels))
            stats = rec.get("stats") or {}
            out.extend(samples_from_dict(
                stats, labels=labels,
                counters=tuple(stats),   # worker counters only grow
            ))
        return out

    registry.register(group, plane_samples)


def register_tracer(
    registry: MetricsRegistry, tracer: Any, *, group: str = "tracer",
) -> None:
    """Expose a ``SpanTracer``'s own health (buffered/emitted/dropped
    event counts, ring census) under ``group`` — the observability plane
    watching itself, so silent ring-buffer truncation shows up on the
    same dashboard as the series it would bias."""

    def tracer_samples() -> list[Sample]:
        return samples_from_dict(
            tracer.stats(), counters=("emitted", "dropped"),
        )

    registry.register(group, tracer_samples)
