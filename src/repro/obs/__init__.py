"""Unified observability plane: spans, trace export, metrics registry.

Three pieces, one import surface:

* :mod:`repro.obs.tracer` — :class:`SpanTracer`, a lock-light per-thread
  ring-buffer recorder for the request lifecycle (submit → queued →
  granted → step → complete/failed), arbiter, cache, and pool events.
  :func:`get_tracer` returns the process-wide default instance every
  dispatch component falls back to.
* :mod:`repro.obs.export` — Chrome trace-event / Perfetto JSON export
  (:func:`to_chrome_trace` / :func:`write_chrome_trace`), structural
  validation (:func:`validate_trace`), and analysis helpers
  (:func:`step_spans`, :func:`worker_overlap`, :func:`composed_spans` —
  the latter extracts the batch composer's shared-decode spans and their
  per-tenant share fan-out).
* :mod:`repro.obs.registry` — :class:`MetricsRegistry`, a typed
  pull-based registry with JSON and Prometheus text exposition, plus
  adapters (:func:`register_dispatch`, :func:`register_cache`,
  :func:`register_tracer`, :func:`register_worker_plane`) over the
  dispatch layer's snapshot dicts.

Multi-process traces: :class:`TraceEvent` carries a ``pid`` (1 for the
parent), and ``to_chrome_trace(..., extra_events=plane.trace_events())``
merges a worker plane's parent-clock, pid-stamped spans into one
Perfetto trace with per-process track groups.

This package imports nothing from :mod:`repro.dispatch` or
:mod:`repro.serving` — those layers depend on this one, never the
reverse.
"""

from .export import (
    composed_spans,
    step_spans,
    to_chrome_trace,
    validate_trace,
    worker_overlap,
    write_chrome_trace,
)
from .registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Sample,
    register_cache,
    register_dispatch,
    register_tracer,
    register_worker_plane,
    samples_from_dict,
)
from .tracer import SpanTracer, TraceEvent, get_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Sample",
    "SpanTracer",
    "TraceEvent",
    "composed_spans",
    "get_tracer",
    "register_cache",
    "register_dispatch",
    "register_tracer",
    "register_worker_plane",
    "samples_from_dict",
    "step_spans",
    "to_chrome_trace",
    "validate_trace",
    "worker_overlap",
    "write_chrome_trace",
]
