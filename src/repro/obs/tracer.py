"""Span tracer: lock-light per-thread ring-buffer event recorder.

The paper's own method, turned into infrastructure: Nimble had to *measure*
the scheduling gap (Fig. 2) before it could remove it, and every dispatch
claim this repo makes (multi-worker overlap, sub-tick grant latency, flat
per-grant CPU) is currently proven only by counters buried in tests.  The
tracer records the full request lifecycle — ``submit → queued → granted →
step[i] → complete`` — plus arbiter events (grant, park, wake, timed
tick), schedule-cache events (build spans, hits, byte-evictions), and
stepper-pool occupancy transitions, correlated by request id + lane +
recording thread, so :mod:`repro.obs.export` can render the overlap
``chrome://tracing`` / Perfetto actually shows.

Design constraints (DESIGN.md §observability):

* **Disabled is a no-op.**  Every instrumented hot path guards with one
  branch — ``if tracer.enabled: tracer.instant(...)`` — so a disabled
  tracer costs a single attribute load + comparison and never builds the
  event's arguments.  The emit methods *also* re-check ``enabled``, so an
  unguarded call site is still safe, just marginally slower.
* **Thread-owned ring buffers.**  Each recording thread appends to its
  own bounded ring (``collections.deque(maxlen=...)``) reached through
  ``threading.local`` — the only shared lock is taken once per thread,
  at first emit, to register the ring for draining.  No emit ever
  contends with another thread's emit.
* **Bounded and honest.**  Rings drop the oldest events once full;
  per-ring ``emitted`` counters make the drop count visible
  (:meth:`SpanTracer.stats`), mirroring the metrics layer's windowed
  ``dropped`` accounting.
* **Draining is cooperative.**  :meth:`SpanTracer.drain` snapshots every
  ring; a ring owned by a live, still-emitting thread is copied with a
  bounded retry (a concurrent append can invalidate one copy attempt).
  Rings of dead threads stay registered so their events survive into the
  export.

Event phases follow the Chrome trace-event vocabulary so the exporter is
a near-passthrough: ``X`` complete spans, ``i`` instants, ``b``/``e``
async begin/end (one async track per request id), ``C`` counters.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, NamedTuple, Optional


class TraceEvent(NamedTuple):
    """One drained trace event, stamped with its recording thread.

    ``ts`` is the tracer clock's reading at the event (span start for
    ``X`` events), ``dur`` the span duration in the same unit (0.0 for
    non-spans), ``ph`` the Chrome trace-event phase (``X``/``i``/``b``/
    ``e``/``C``), ``rid`` the request id for request-correlated events
    (``None`` otherwise), ``lane`` the tenant lane (``""`` otherwise),
    and ``tid``/``thread`` the recording thread's ident and name.
    ``pid`` identifies the recording *process* for multi-process traces
    (worker-plane spans merge under their worker's OS pid; the parent's
    own events default to 1), giving the Perfetto export one track group
    per process."""

    ts: float
    ph: str
    cat: str
    name: str
    dur: float
    rid: Optional[int]
    lane: str
    args: Optional[dict]
    tid: int
    thread: str
    pid: int = 1


class _Ring:
    """One thread's event ring: owned (appended) by exactly one thread,
    registered once so drains can find it.  ``emitted`` counts every
    append, so ``emitted - len(buf)`` is the drop count."""

    __slots__ = ("ident", "name", "buf", "emitted")

    def __init__(self, ident: int, name: str, cap: int) -> None:
        self.ident = ident
        self.name = name
        self.buf: deque = deque(maxlen=cap)
        self.emitted = 0


class SpanTracer:
    """Per-thread ring-buffer recorder for dispatch-plane trace events.

    One instance is typically shared by a whole dispatch stack (the
    module-level tracer from :func:`get_tracer` is the default everywhere)
    and starts **disabled**: instrumented code runs at production speed
    until :meth:`enable` is called.  All methods are safe from any
    thread; emits never take a shared lock (see the module docstring for
    the ownership contract).
    """

    def __init__(
        self,
        *,
        buffer_size: int = 65536,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        if buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        self.enabled = False
        self.buffer_size = buffer_size
        self.clock = clock
        self._local = threading.local()
        self._mu = threading.Lock()          # ring registry only
        self._rings: list[_Ring] = []

    # -- lifecycle ---------------------------------------------------------

    def enable(self) -> "SpanTracer":
        """Start recording (idempotent); returns ``self`` for chaining."""
        self.enabled = True
        return self

    def disable(self) -> "SpanTracer":
        """Stop recording (idempotent); buffered events stay drainable."""
        self.enabled = False
        return self

    def clear(self) -> None:
        """Drop every buffered event and reset drop counters.  Rings stay
        registered (their owning threads hold thread-local references)."""
        with self._mu:
            for ring in self._rings:
                ring.buf.clear()
                ring.emitted = 0

    # -- recording (each thread appends only to its own ring) --------------

    def _ring(self) -> _Ring:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            t = threading.current_thread()
            ring = _Ring(t.ident or 0, t.name, self.buffer_size)
            self._local.ring = ring
            with self._mu:                   # once per (thread, tracer)
                self._rings.append(ring)
        return ring

    def instant(
        self,
        name: str,
        *,
        cat: str = "dispatch",
        lane: str = "",
        rid: Optional[int] = None,
        args: Optional[dict] = None,
    ) -> None:
        """Record a point-in-time event (Chrome phase ``i``)."""
        if not self.enabled:
            return
        ring = self._ring()
        ring.emitted += 1
        ring.buf.append((self.clock(), "i", cat, name, 0.0, rid, lane, args))

    def complete(
        self,
        name: str,
        ts: float,
        dur: float,
        *,
        cat: str = "dispatch",
        lane: str = "",
        rid: Optional[int] = None,
        args: Optional[dict] = None,
    ) -> None:
        """Record a finished span (Chrome phase ``X``): ``ts`` is the span
        start on this tracer's clock, ``dur`` its duration.  Callers
        already hold both timestamps (they bracketed the work for
        metrics), so no begin/end pairing state is needed — a span is one
        append, recorded at its end."""
        if not self.enabled:
            return
        ring = self._ring()
        ring.emitted += 1
        ring.buf.append((ts, "X", cat, name, max(0.0, dur), rid, lane, args))

    def async_begin(
        self,
        name: str,
        rid: int,
        *,
        cat: str = "request",
        lane: str = "",
        args: Optional[dict] = None,
    ) -> None:
        """Open the async span for request ``rid`` (Chrome phase ``b``) —
        one async track per request in the exported trace."""
        if not self.enabled:
            return
        ring = self._ring()
        ring.emitted += 1
        ring.buf.append((self.clock(), "b", cat, name, 0.0, rid, lane, args))

    def async_end(
        self,
        name: str,
        rid: int,
        *,
        cat: str = "request",
        lane: str = "",
        args: Optional[dict] = None,
    ) -> None:
        """Close request ``rid``'s async span (Chrome phase ``e``).  The
        ``name``/``cat`` must match the opening :meth:`async_begin`."""
        if not self.enabled:
            return
        ring = self._ring()
        ring.emitted += 1
        ring.buf.append((self.clock(), "e", cat, name, 0.0, rid, lane, args))

    def counter(
        self,
        name: str,
        value: float,
        *,
        cat: str = "dispatch",
        series: str = "value",
    ) -> None:
        """Record a counter-track sample (Chrome phase ``C``) — e.g. the
        stepper pool's busy-worker count at an occupancy transition."""
        if not self.enabled:
            return
        ring = self._ring()
        ring.emitted += 1
        ring.buf.append(
            (self.clock(), "C", cat, name, 0.0, None, "", {series: value})
        )

    # -- draining ----------------------------------------------------------

    @staticmethod
    def _snapshot(buf: deque) -> list:
        # a live owner may append mid-copy (deques forbid mutation during
        # iteration); retry a few times, then trade one drop-window of
        # accuracy for progress by pop-free best effort
        for _ in range(8):
            try:
                return list(buf)
            except RuntimeError:
                continue
        return []

    def drain(self) -> list[TraceEvent]:
        """Snapshot every thread's ring into one time-sorted event list.

        Non-destructive: buffers keep their contents (use :meth:`clear`
        between capture windows).  Safe while recording threads are live —
        each ring is copied with a bounded retry against concurrent
        appends."""
        with self._mu:
            rings = list(self._rings)
        out: list[TraceEvent] = []
        for ring in rings:
            for ev in self._snapshot(ring.buf):
                out.append(TraceEvent(*ev, tid=ring.ident, thread=ring.name))
        out.sort(key=lambda e: e.ts)
        return out

    def stats(self) -> dict:
        """Recorder state: enabled flag, per-thread ring count, buffered
        and emitted event totals, and how many events the bounded rings
        have dropped (``emitted - buffered``, summed)."""
        with self._mu:
            rings = list(self._rings)
        buffered = sum(len(r.buf) for r in rings)
        emitted = sum(r.emitted for r in rings)
        return {
            "enabled": self.enabled,
            "threads": len(rings),
            "buffered": buffered,
            "emitted": emitted,
            "dropped": emitted - buffered,
            "buffer_size": self.buffer_size,
        }


_GLOBAL = SpanTracer()


def get_tracer() -> SpanTracer:
    """The process-wide default tracer every dispatch component falls back
    to when constructed without an explicit ``tracer=``.  Starts disabled;
    ``get_tracer().enable()`` turns on capture for the whole stack."""
    return _GLOBAL
