"""Trace export: serialize tracer rings to Chrome trace-event / Perfetto JSON.

``chrome://tracing`` and https://ui.perfetto.dev both consume the Chrome
trace-event JSON object format (a ``traceEvents`` array plus metadata).
The mapping from :class:`repro.obs.tracer.TraceEvent`:

* one **thread track per recording thread** — stepper workers appear as
  ``repro-dispatch-step[pool-N]`` rows, so the multi-worker overlap that
  ``test_stepper_pool`` proves numerically becomes *visible*: ``X``
  (complete) span events carry ``ts``/``dur`` on their recording
  thread's track, with lane and request id in ``args``;
* one **async track per request** — ``b``/``e`` pairs share
  ``id == rid`` (and category ``request``), so each request renders as
  one submit→complete bar regardless of which worker threads served it;
* **counter tracks** (``C``) for stepper-pool occupancy;
* ``M`` metadata events name each thread track;
* one **process track group per recording process** — each
  :class:`TraceEvent` carries a ``pid`` (the parent's events default to
  1; worker-plane spans arrive stamped with their worker's OS pid and a
  parent-clock timestamp via the spawn-time clock-offset handshake), and
  ``process_name`` metadata labels each group, so a multi-process
  serving plane renders as one merged Perfetto trace with per-process
  tracks.  Pass the plane's collected spans as ``extra_events=``.

Timestamps are exported in microseconds relative to the earliest drained
event, which is what both viewers expect.

:func:`validate_trace` is the structural gate ``make trace-smoke`` and
the tests run over every exported trace: phase-specific required fields,
non-negative durations, and balanced async begin/end pairs — a trace that
fails it would load blank (or not at all) in the viewers.
"""

from __future__ import annotations

import json
from typing import Any, Iterable, Optional, Union

from .tracer import SpanTracer, TraceEvent

_PARENT_PID = 1              # default pid: the dispatching (parent) process


def _args(ev: TraceEvent) -> dict:
    out = dict(ev.args) if ev.args else {}
    if ev.lane:
        out.setdefault("lane", ev.lane)
    if ev.rid is not None:
        out.setdefault("rid", ev.rid)
    return out


def to_chrome_trace(
    events_or_tracer: Union[SpanTracer, Iterable[TraceEvent]],
    *,
    extra_events: Optional[Iterable[TraceEvent]] = None,
) -> dict:
    """Convert drained events (or a tracer, drained here) into a Chrome
    trace-event JSON object — ``json.dump`` the result and load it in
    ``chrome://tracing`` or ui.perfetto.dev.

    Deterministic given the events: microsecond timestamps rebased to the
    earliest event, one metadata-named track per recording thread, one
    async track per request id.  ``extra_events`` merges a second event
    stream — ``WorkerPlane.trace_events()``, already parent-clock and
    pid-stamped — into the same trace; events sort together by timestamp
    and each distinct pid gets its own ``process_name``-labelled track
    group."""
    if isinstance(events_or_tracer, SpanTracer):
        events = events_or_tracer.drain()
    else:
        events = list(events_or_tracer)
    if extra_events is not None:
        events = sorted(
            list(events) + list(extra_events), key=lambda e: e.ts
        )
    origin = min((e.ts for e in events), default=0.0)
    # label process tracks only when the trace actually spans processes —
    # a single-process trace keeps its metadata to thread names alone
    multi_pid = len({getattr(e, "pid", _PARENT_PID) for e in events}) > 1
    out: list[dict] = []
    threads_seen: set[tuple[int, int]] = set()
    pids_seen: set[int] = set()
    for ev in events:
        pid = getattr(ev, "pid", _PARENT_PID)
        if multi_pid and pid not in pids_seen:
            pids_seen.add(pid)
            label = (
                "dispatcher (parent)" if pid == _PARENT_PID
                else f"worker pid={pid}"
            )
            out.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": label},
            })
        if (pid, ev.tid) not in threads_seen:
            threads_seen.add((pid, ev.tid))
            out.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": ev.tid,
                "args": {"name": ev.thread},
            })
        ts_us = (ev.ts - origin) * 1e6
        rec: dict[str, Any] = {
            "ph": ev.ph, "name": ev.name, "cat": ev.cat,
            "pid": pid, "tid": ev.tid, "ts": ts_us,
        }
        if ev.ph == "X":
            rec["dur"] = ev.dur * 1e6
            rec["args"] = _args(ev)
        elif ev.ph == "i":
            rec["s"] = "t"               # instant scope: thread
            rec["args"] = _args(ev)
        elif ev.ph in ("b", "e"):
            rec["id"] = str(ev.rid)
            rec["args"] = _args(ev)
        elif ev.ph == "C":
            rec["args"] = dict(ev.args or {})
        else:                            # unknown phase: keep args, let the
            rec["args"] = _args(ev)      # validator flag it
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(
    path: str,
    events_or_tracer: Union[SpanTracer, Iterable[TraceEvent]],
    *,
    extra_events: Optional[Iterable[TraceEvent]] = None,
) -> dict:
    """Export to ``path`` as JSON; returns the trace object written.
    ``extra_events`` merges a worker plane's collected spans (see
    :func:`to_chrome_trace`)."""
    trace = to_chrome_trace(events_or_tracer, extra_events=extra_events)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


_KNOWN_PHASES = ("X", "i", "b", "e", "C", "M")


def validate_trace(trace: Any) -> list[str]:
    """Structural validation against the trace-event schema; returns one
    error string per violation (empty list == loadable).

    Checks: top-level shape, JSON-serializability, required per-phase
    fields (``ts``/``pid``/``tid`` everywhere but metadata, ``dur >= 0``
    on complete events, ``id`` on async events), known phases only, and
    balanced async begin/end pairs per ``(cat, id)``."""
    errors: list[str] = []
    if not isinstance(trace, dict) or not isinstance(
        trace.get("traceEvents"), list
    ):
        return ["trace must be a dict with a 'traceEvents' list"]
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as exc:
        errors.append(f"trace is not JSON-serializable: {exc}")
    opens: dict[tuple, int] = {}
    for i, ev in enumerate(trace["traceEvents"]):
        if not isinstance(ev, dict):
            errors.append(f"event[{i}]: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _KNOWN_PHASES:
            errors.append(f"event[{i}]: unknown phase {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev or "tid" not in ev:
            errors.append(f"event[{i}] ({ph}): missing name/pid/tid")
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"event[{i}] ({ph} {ev.get('name')!r}): missing ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"event[{i}] (X {ev.get('name')!r}): bad dur {dur!r}"
                )
        if ph in ("b", "e"):
            if "id" not in ev:
                errors.append(f"event[{i}] ({ph} {ev.get('name')!r}): no id")
            else:
                key = (ev.get("cat"), ev["id"])
                opens[key] = opens.get(key, 0) + (1 if ph == "b" else -1)
    for key, depth in opens.items():
        if depth != 0:
            errors.append(
                f"async track {key}: unbalanced begin/end (depth {depth})"
            )
    return errors


def step_spans(trace: dict, cat: str = "step") -> list[tuple]:
    """Every ``X`` span of category ``cat`` as ``(tid, start_us, end_us,
    name)`` tuples — the raw material for overlap analysis."""
    out = []
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "X" and ev.get("cat") == cat:
            out.append(
                (ev["tid"], ev["ts"], ev["ts"] + ev.get("dur", 0.0),
                 ev.get("name", ""))
            )
    return out


def composed_spans(trace: dict) -> list[tuple]:
    """Composed-step decode spans and their per-tenant share fan-out, as
    ``(name, start_us, dur_us, args)`` tuples: every ``X`` span named
    ``composed:<host>`` (one shared device step serving N tenants — the
    batch composer emits one per group quantum, with occupancy and lane
    count in ``args``) followed in emission order by its
    ``composed_share`` instants (``cat="composer"``, one per tenant with
    that step's token count).  The raw material for per-tenant share and
    coalesce-rate analysis straight from a trace."""
    out = []
    for ev in trace.get("traceEvents", []):
        name = ev.get("name", "")
        if ev.get("ph") == "X" and name.startswith("composed:"):
            out.append(
                (name, ev["ts"], ev.get("dur", 0.0), ev.get("args", {}))
            )
        elif ev.get("ph") == "i" and name == "composed_share":
            out.append((name, ev["ts"], 0.0, ev.get("args", {})))
    return out


def worker_overlap(trace: dict, cat: str = "step") -> tuple[int, bool]:
    """``(worker_tracks, overlapped)``: how many distinct threads recorded
    ``cat`` spans, and whether any two spans on *different* threads
    overlap in time — the visual claim (≥2 workers stepping
    concurrently) reduced to a checkable boolean.  Linear sweep over the
    spans sorted by start time."""
    spans = sorted(step_spans(trace, cat), key=lambda s: s[1])
    tids = {s[0] for s in spans}
    # best_end: latest span end seen; other_end: latest end on any thread
    # OTHER than best's — a new span overlapping either of the right one
    # proves two threads were mid-span at once
    best_end, best_tid = float("-inf"), None
    other_end = float("-inf")
    overlapped = False
    for tid, start, end, _name in spans:
        if (tid != best_tid and start < best_end) or start < other_end:
            overlapped = True
            break
        if end > best_end:
            if tid != best_tid:
                other_end = best_end
            best_end, best_tid = end, tid
        elif tid != best_tid and end > other_end:
            other_end = end
    return len(tids), overlapped
