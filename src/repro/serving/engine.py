"""Serving engine: continuous batching over AoT-sealed prefill/decode steps.

The Nimble story applied to inference serving: both step functions are
scheduled **once** ahead of time (traced, compiled, memory reserved — the
task schedule), and the request loop only *submits* them.  Per-request state
lives in batch slots of a shared KV cache; each slot decodes at its own
offset (``kv_cache["pos"]`` is per-slot), so finished requests are replaced
without disturbing neighbours — iteration-level continuous batching.

Sealed executables are obtained through a ``repro.dispatch.ScheduleCache``
rather than compiled inline: prefill runs per request into its slot, padded
to a bucket length chosen by a ``repro.dispatch.bucketing`` policy, and each
(bucket, config) executable is built at most once — shared across engines
that use the same cache, and evicted LRU under shape churn.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aot import ScheduleKey
from repro.dispatch.bucketing import BucketingPolicy, make_policy
from repro.dispatch.cache import ScheduleCache
from repro.models import decode_step, forward, init_cache, init_model
from repro.models.transformer import encode_memory
from repro.obs.tracer import get_tracer


@dataclasses.dataclass
class Request:
    """One generation request: prompt in, tokens out, engine-stamped
    timestamps (``t_submit``/``t_first``/``t_done``) for latency metrics.
    The unit of traffic for both the engine and the dispatch layer.

    ``truncated`` is set when the engine stopped the request early because
    its context window filled (``prompt + generated`` reached ``max_len``)
    — the caller got fewer than ``max_new_tokens`` tokens and this flag is
    the signal saying why.  ``error`` is set (with ``done``) when the
    request was failed rather than served — an unservable prompt reaching
    admission, or a retire racing a direct submit — so no request ever
    silently vanishes.  ``deadline`` (0.0 — none) is stamped by the
    dispatcher's SLO plane at admission when the lane carries a latency
    target: submit time plus target, on the SLO policy's clock — the
    value overload shedding compares against.  ``state`` is the explicit
    lifecycle state (:class:`repro.dispatch.lifecycle.RequestState`)
    stamped by the dispatcher's lifecycle tracker; requests submitted
    straight to an engine keep the empty string and are exempt from
    lifecycle enforcement (and from journaling)."""

    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 16
    tenant: str = ""                   # set by the dispatcher (multi-tenant)
    model: str = ""
    deadline: float = 0.0              # SLO deadline (0.0: best-effort)
    on_complete: Optional[Callable] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    # filled by the engine:
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False            # finished early: context window full
    error: Optional[str] = None        # failed (not served): why
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    state: str = ""                    # dispatcher lifecycle state ("" = untracked)


@dataclasses.dataclass
class EngineStats:
    """Per-engine counters: compiles, steps, token and wall-time totals
    (prefill vs decode split)."""

    prefill_compiles: int = 0
    decode_compiles: int = 0
    steps: int = 0
    tokens_out: int = 0          # decode-produced tokens only
    prefill_tokens: int = 0      # first tokens, produced by prefill
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def decode_tok_per_s(self) -> float:
        """Decode-only token throughput (tokens out / decode seconds)."""
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServingEngine:
    """AoT-scheduled batched serving for any registered architecture."""

    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int = 4,
        max_len: int = 256,
        prompt_buckets: tuple[int, ...] = (32, 128),
        bucketing: Any = None,
        schedule_cache: Optional[ScheduleCache] = None,
        warmup: bool = True,
        greedy: bool = True,
        device: Any = None,
        tracer: Any = None,
    ) -> None:
        if cfg.family in ("hybrid", "ssm"):
            raise NotImplementedError(
                "slot-replacement serving needs re-settable recurrent state; "
                "use batch decode directly for SSM/hybrid archs"
            )
        self.cfg = cfg
        # `device` pins this engine's weights, KV cache, and executables to
        # one device — the serving analogue of the paper's stream
        # assignment: per-engine steppers over engines on *different*
        # devices overlap decode with no shared execution queue.  On CPU,
        # expose extra host devices with
        # XLA_FLAGS=--xla_force_host_platform_device_count=N.
        self.device = device
        if device is not None:
            params = jax.device_put(params, device)
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        # `bucketing` (policy/spec) generalizes the old `prompt_buckets`
        # tuple, which remains as the explicit-buckets shorthand.
        self.bucketing: BucketingPolicy = make_policy(
            bucketing if bucketing is not None else prompt_buckets
        )
        # explicit None-check: an empty ScheduleCache is falsy (__len__ == 0)
        self.schedule_cache = (
            ScheduleCache(capacity=32) if schedule_cache is None else schedule_cache
        )
        self.greedy = greedy
        self.stats = EngineStats()
        self.tracer = tracer if tracer is not None else get_tracer()

        # sealed-executable identity beyond arg shapes: anything that changes
        # the traced computation without changing input shapes.  The device
        # is part of the identity: an executable compiled for device 0 must
        # not be replayed against arrays committed to device 1.
        self._key_options = (
            ("cfg", repr(cfg)),
            ("max_len", max_len),
            ("max_slots", max_slots),
            ("device", repr(device) if device is not None else ""),
        )

        # --- AoT scheduling: seal the step executables through the cache --
        self.kv_cache = init_cache(cfg, max_slots, max_len)
        if device is not None:
            self.kv_cache = jax.device_put(self.kv_cache, device)
        # per-engine memo of bucket -> ScheduleKey: key construction flattens
        # the whole params pytree, too costly per admitted request.  Only the
        # *key* is memoized — executables stay owned by the shared cache, so
        # its LRU eviction and invalidate()/clear() genuinely govern their
        # lifetime (an evicted bucket transparently rebuilds on next use).
        self._prefill_keys: "OrderedDict[int, ScheduleKey]" = OrderedDict()
        self._prefill_key_cap = 64
        self._decode = self._get_decode_exec()
        if warmup:
            for b in self._warm_buckets():
                self._get_prefill_exec(b)

        self.slots: list[Optional[Request]] = [None] * max_slots
        self.queue: list[Request] = []
        self._next_tok = np.zeros((max_slots, 1), np.int32)
        # thread-safety contract: the engine is single-stepper — exactly one
        # thread may drive step() at a time.  Under the dispatch layer that
        # thread is whoever holds this engine's lane step-lock (one
        # dedicated stepper per engine in AsyncDispatcher's per-engine
        # mode; the loop thread in single mode).  This guard turns an
        # accidental second stepper — e.g. an engine registered with two
        # dispatchers, or a caller stepping directly while dispatched —
        # into a loud error instead of corrupted KV state.
        self._step_mu = threading.Lock()
        self._retired = False
        # engine-side submit hook (installed by a dispatcher): called after
        # every direct submit() so directly-enqueued work reaches the
        # indexed ready set — without it, pool grants never see traffic
        # that bypassed the dispatcher's front door
        self._submit_hook: Optional[Callable[[], None]] = None

    def retire(self) -> None:
        """Lane-retire hook: release this engine's serving lifecycle.

        Called by ``Dispatcher.unregister_model`` after the lane drained.
        Refuses all further submissions (``validate_request`` raises) and
        drops the per-engine ``ScheduleKey`` memo so the shared schedule
        cache's LRU — not a dead tenant's memo — governs how long the
        sealed executables stay referenced.  Requests still queued (a
        direct ``submit`` racing the retire — there are none after a
        dispatcher drain) are FAILED loudly: each is completed with
        ``error`` set and its ``on_complete`` fired, never silently
        dropped.  Idempotent.
        """
        self._retired = True
        stranded, self.queue = list(self.queue), []
        self._prefill_keys.clear()
        for req in stranded:
            self._fail_request(req, "engine retired with request queued")

    def _fail_request(self, req: Request, why: str) -> None:
        """Complete ``req`` as failed: ``done`` + ``error`` set, terminal
        timestamp stamped, ``on_complete`` fired (no locks held)."""
        req.error = why
        req.done = True
        req.t_done = time.perf_counter()
        cb = req.on_complete
        if cb is not None:
            cb(req.model, req)

    def set_submit_hook(self, hook: Optional[Callable[[], None]]) -> None:
        """Install (or clear, with ``None``) the direct-submit hook.

        The hook fires after every :meth:`submit` appends to the engine
        queue.  ``Dispatcher.register_model`` points it at the lane's
        ready-index recompute, so work submitted to the engine directly —
        bypassing the dispatcher — still lands in the indexed ready set
        and pool grants (and the batch composer's refill path) can see
        it.  The hook must be fast and must not call back into the
        engine."""
        self._submit_hook = hook

    # -- sealed executables through the schedule cache ---------------------
    _EXEC_ARENA_FLOOR = 4096     # conservative floor: never report ~free

    def _exec_arena_bytes(self, *extra_shapes: tuple) -> int:
        """Reserved-memory estimate for one step executable, derived from
        its output buffer shapes: every step returns the full KV cache
        (the dominant term — without donation XLA materializes a fresh
        copy) plus the next-token array.  ``extra_shapes`` adds
        ``(shape, dtype)`` pairs for per-executable outputs/temps (e.g. a
        prefill's padded token buffer).  Byte-budget eviction needs a
        non-zero number here: raw executables carry no TaskSchedule stats,
        and reporting 0 would make them invisible to the budget.  The
        KV-cache term is memoized (shapes are fixed for the engine's
        lifetime): this runs on every request admission, and the estimate
        only matters on a cache miss."""
        kv = getattr(self, "_kv_arena_bytes", None)
        if kv is None:
            kv = self._kv_arena_bytes = sum(
                int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
                for leaf in jax.tree_util.tree_leaves(self.kv_cache)
            )
        total = kv
        for shape, dtype in extra_shapes:
            total += int(np.prod(shape)) * jnp.dtype(dtype).itemsize
        return max(self._EXEC_ARENA_FLOOR, total)

    def _warm_buckets(self) -> tuple[int, ...]:
        static = self.bucketing.static_buckets()
        if static is None:
            return ()
        return tuple(b for b in static if b <= self.max_len)

    @property
    def prompt_buckets(self) -> tuple[int, ...]:
        """Bucket family currently pre-sealable (exact policies: empty)."""
        return self._warm_buckets()

    def _get_decode_exec(self):
        key = ScheduleKey.from_call(
            decode_step,
            (self.params, self.kv_cache,
             jax.ShapeDtypeStruct((self.max_slots, 1), jnp.int32)),
            self._key_options,
            fn_id=f"serving.decode/{self.cfg.name}",
        )

        def build():
            exe = jax.jit(self._decode_impl).lower(
                self.params, self.kv_cache,
                jax.ShapeDtypeStruct((self.max_slots, 1), jnp.int32),
            ).compile()
            self.stats.decode_compiles += 1
            return exe

        # no pin: the key's fn_id is an explicit string (no id() component
        # to protect), and pinning params would keep a dropped engine's
        # whole weight pytree alive in a shared cache until eviction
        return self.schedule_cache.get_or_build(
            key, build,
            arena_bytes=self._exec_arena_bytes(
                ((self.max_slots, 1), jnp.int32)
            ),
        )

    def _prefill_key(self, bucket: int) -> ScheduleKey:
        key = self._prefill_keys.get(bucket)
        if key is not None:
            self._prefill_keys.move_to_end(bucket)
            return key
        key = ScheduleKey.from_call(
            decode_step,
            (self.params,
             jax.ShapeDtypeStruct((1, bucket), jnp.int32),
             self.kv_cache),
            self._key_options,
            fn_id=f"serving.prefill/{self.cfg.name}",
        )
        self._prefill_keys[bucket] = key
        while len(self._prefill_keys) > self._prefill_key_cap:
            self._prefill_keys.popitem(last=False)
        return key

    def _get_prefill_exec(self, bucket: int):
        key = self._prefill_key(bucket)

        def build():
            exe = jax.jit(self._prefill_dyn).lower(
                self.params,
                jax.ShapeDtypeStruct((1, bucket), jnp.int32),
                self.kv_cache,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
            ).compile()
            self.stats.prefill_compiles += 1
            return exe

        return self.schedule_cache.get_or_build(
            key, build,
            arena_bytes=self._exec_arena_bytes(((1, bucket), jnp.int32)),
        )

    # -- sealed step bodies ------------------------------------------------
    def _decode_impl(self, params, cache, tokens):
        logits, cache = decode_step(params, cache, tokens, self.cfg)
        nxt = jnp.argmax(logits[:, :, : self.cfg.vocab], axis=-1).astype(jnp.int32)
        return nxt, cache

    def _prefill_dyn(self, params, tokens, cache, slot, true_len):
        """Prefill one request (padded to a bucket) into cache slot `slot`."""
        cfg = self.cfg
        B1, P = tokens.shape
        # run the padded prompt through decode-style attention with cache,
        # writing K/V at offsets [0, P) of the slot.
        sub_cache = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)
            if c.ndim >= 2 and c.shape[1] == self.max_slots
            else c,
            {k: v for k, v in cache.items() if k != "pos"},
        )
        sub_cache["pos"] = jnp.zeros((1,), jnp.int32)
        logits, sub_cache = decode_step(params, sub_cache, tokens, cfg)
        # next token from the true last prompt position (pre-pad)
        last = logits[0, true_len - 1, : cfg.vocab]
        nxt = jnp.argmax(last).astype(jnp.int32)
        # write slot state back
        new_cache = {}
        for k, v in cache.items():
            if k == "pos":
                new_cache[k] = v.at[slot].set(true_len)
            elif v.ndim >= 2 and v.shape[1] == self.max_slots:
                new_cache[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, sub_cache[k].astype(v.dtype), slot, axis=1
                )
            else:
                new_cache[k] = v
        return nxt, new_cache

    def compose_key(self) -> tuple:
        """Batched-decode compatibility key for the batch composer.

        Two engines whose keys are equal replay the *same* sealed decode
        executable over interchangeable KV-cache slots, so their lanes'
        requests may share one batched decode step: the key is the sealed
        executable's identity beyond shapes (``_key_options``: cfg, device,
        ``max_len``, ``max_slots``), the bucketing policy (prefill shapes
        must land in the same bucket family), and the **weights' object
        identity** — same config with different parameters is a different
        computation and must never coalesce."""
        return (self._key_options, repr(self.bucketing), id(self.params))

    # -- request flow --------------------------------------------------------
    def validate_request(self, req: Request) -> None:
        """Reject requests this engine can never serve.

        Dispatchers call this at submit time so an unservable prompt raises
        on the *submitter* (synchronous backpressure semantics), not later
        on a stepping thread where it would poison every tenant's futures.
        A retired engine (see :meth:`retire`) rejects everything.
        """
        if self._retired:
            raise RuntimeError("engine is retired; it no longer serves")
        self._bucket(len(req.prompt))          # ValueError if unservable

    def submit(self, req: Request) -> None:
        """Enqueue ``req`` for admission on a later :meth:`step` (stamps
        ``t_submit`` unless the dispatcher already did), then fires the
        installed submit hook so directly-submitted work becomes visible
        to the dispatch layer's ready index."""
        if not req.t_submit:         # dispatcher may have stamped lane entry
            req.t_submit = time.perf_counter()
        self.queue.append(req)
        hook = self._submit_hook
        if hook is not None:
            hook()

    def free_slots(self) -> int:
        """Seats available right now (admission control hook), clamped at
        0 — once the queue holds more requests than free seats there is
        no capacity, not negative capacity (admission-control arithmetic
        built on this must never see a negative)."""
        return max(0, sum(1 for s in self.slots if s is None) - len(self.queue))

    @property
    def idle(self) -> bool:
        """True when no request is queued and every batch slot is free."""
        return not self.queue and all(s is None for s in self.slots)

    def _bucket(self, plen: int) -> int:
        b = self.bucketing.bucket(plen)
        if b > self.max_len:
            raise ValueError(
                f"prompt bucket {b} exceeds engine max_len {self.max_len}"
            )
        return b

    def _finish(self, req: Request, slot: int) -> None:
        req.done = True
        req.t_done = time.perf_counter()
        self.slots[slot] = None
        # reset the slot's write offset for the next occupant
        self.kv_cache["pos"] = self.kv_cache["pos"].at[slot].set(0)

    def _admit(self) -> list[Request]:
        finished: list[Request] = []
        for slot in range(self.max_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            # validate BEFORE popping: an unservable directly-submitted
            # prompt (dispatcher submits are validated up front) is failed
            # and returned as finished — popping first and then raising
            # would lose the request and poison the stepping thread
            req = self.queue[0]
            plen = len(req.prompt)
            try:
                b = self._bucket(plen)
            except ValueError as exc:
                self.queue.pop(0)
                self._fail_request(req, f"unservable prompt: {exc}")
                finished.append(req)
                continue
            self.queue.pop(0)
            exe = self._get_prefill_exec(b)    # schedule-cache hit when warm
            padded = np.zeros((1, b), np.int32)
            padded[0, :plen] = req.prompt
            t0 = time.perf_counter()
            nxt, self.kv_cache = exe(
                self.params, jnp.asarray(padded), self.kv_cache,
                jnp.int32(slot), jnp.int32(plen),
            )
            dt = time.perf_counter() - t0
            self.stats.prefill_s += dt
            if self.tracer.enabled:
                # nests inside the dispatcher's step span (same thread)
                self.tracer.complete(
                    "prefill", t0, dt, cat="engine", rid=req.rid,
                    args={"bucket": b},
                )
            req.t_first = time.perf_counter()
            req.generated.append(int(nxt))
            self.stats.prefill_tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                # e.g. a 1-token request: done at prefill, never seats
                self._finish(req, slot)
                finished.append(req)
                continue
            self._next_tok[slot, 0] = int(nxt)
            self.slots[slot] = req
        return finished

    def step(self) -> list[Request]:
        """One engine iteration: admit + one decode step for all live slots.

        Returns every request that finished during this step — including
        those admitted and completed within it (they were invisible to the
        old snapshot-based ``run_until_drained``).
        """
        if not self._step_mu.acquire(blocking=False):
            raise RuntimeError(
                "ServingEngine.step() entered concurrently: the engine is "
                "single-stepper; drive it from one thread or lane (e.g. "
                "through a Dispatcher, which serializes per-lane stepping "
                "even with per-engine stepper threads)"
            )
        try:
            return self._step_locked()
        finally:
            self._step_mu.release()

    def _step_locked(self) -> list[Request]:
        finished = self._admit()
        live = [s for s in range(self.max_slots) if self.slots[s] is not None]
        if not live:
            return finished
        t0 = time.perf_counter()
        nxt, self.kv_cache = self._decode(
            self.params, self.kv_cache, jnp.asarray(self._next_tok)
        )
        dt = time.perf_counter() - t0
        self.stats.decode_s += dt
        if self.tracer.enabled:
            self.tracer.complete(
                "decode", t0, dt, cat="engine", args={"live": len(live)}
            )
        self.stats.steps += 1
        nxt_np = np.asarray(nxt)
        for s in live:
            req = self.slots[s]
            req.generated.append(int(nxt_np[s, 0]))
            self._next_tok[s, 0] = nxt_np[s, 0]
            self.stats.tokens_out += 1
            pos_full = len(req.prompt) + len(req.generated)
            if len(req.generated) >= req.max_new_tokens or pos_full >= self.max_len - 1:
                if len(req.generated) < req.max_new_tokens:
                    # context window full before max_new_tokens: the caller
                    # gets fewer tokens than asked — say so, loudly
                    req.truncated = True
                self._finish(req, s)
                finished.append(req)
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Step until queue and slots are empty; raises
        :class:`~repro.dispatch.DrainTimeoutError` if ``max_steps`` pass
        with requests still in flight (mirrors ``Dispatcher``)."""
        from repro.dispatch.dispatcher import DrainTimeoutError

        finished: list[Request] = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if self.idle:
                return finished
        if self.idle:
            return finished
        raise DrainTimeoutError(
            f"engine drain exhausted {max_steps} steps with "
            f"{len(self.queue) + sum(s is not None for s in self.slots)} "
            f"requests still in flight"
        )
