"""Serving engine: continuous batching over AoT-sealed prefill/decode steps.

The Nimble story applied to inference serving: both step functions are
scheduled **once** ahead of time (traced, compiled, memory reserved — the
task schedule), and the request loop only *submits* them.  Per-request state
lives in batch slots of a shared KV cache; each slot decodes at its own
offset (``cache["pos"]`` is per-slot), so finished requests are replaced
without disturbing neighbours — iteration-level continuous batching.

Prefill runs per request into its slot (padded to a bucket length so a small
fixed family of sealed executables covers all prompt lengths).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_cache, init_model
from repro.models.transformer import encode_memory


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 16
    # filled by the engine:
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class EngineStats:
    prefill_compiles: int = 0
    decode_compiles: int = 0
    steps: int = 0
    tokens_out: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServingEngine:
    """AoT-scheduled batched serving for any registered architecture."""

    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int = 4,
        max_len: int = 256,
        prompt_buckets: tuple[int, ...] = (32, 128),
        greedy: bool = True,
    ) -> None:
        if cfg.family in ("hybrid", "ssm"):
            raise NotImplementedError(
                "slot-replacement serving needs re-settable recurrent state; "
                "use batch decode directly for SSM/hybrid archs"
            )
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prompt_buckets = tuple(sorted(prompt_buckets))
        self.greedy = greedy
        self.stats = EngineStats()

        # --- AoT scheduling: seal the step executables ------------------
        self.cache = init_cache(cfg, max_slots, max_len)
        self._decode = jax.jit(self._decode_impl).lower(
            self.params, self.cache,
            jax.ShapeDtypeStruct((max_slots, 1), jnp.int32),
        ).compile()
        self.stats.decode_compiles += 1

        # one sealed prefill executable per prompt bucket; the slot index is
        # a traced scalar (dynamic_update_slice), so slots share executables
        self._prefill_exec: dict[int, Callable] = {}
        for b in self.prompt_buckets:
            self._prefill_exec[b] = jax.jit(self._prefill_dyn).lower(
                self.params,
                jax.ShapeDtypeStruct((1, b), jnp.int32),
                self.cache,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
            ).compile()
            self.stats.prefill_compiles += 1

        self.slots: list[Optional[Request]] = [None] * max_slots
        self.queue: list[Request] = []
        self._next_tok = np.zeros((max_slots, 1), np.int32)

    # -- sealed step bodies ------------------------------------------------
    def _decode_impl(self, params, cache, tokens):
        logits, cache = decode_step(params, cache, tokens, self.cfg)
        nxt = jnp.argmax(logits[:, :, : self.cfg.vocab], axis=-1).astype(jnp.int32)
        return nxt, cache

    def _prefill_dyn(self, params, tokens, cache, slot, true_len):
        """Prefill one request (padded to a bucket) into cache slot `slot`."""
        cfg = self.cfg
        B1, P = tokens.shape
        # run the padded prompt through decode-style attention with cache,
        # writing K/V at offsets [0, P) of the slot.
        sub_cache = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)
            if c.ndim >= 2 and c.shape[1] == self.max_slots
            else c,
            {k: v for k, v in cache.items() if k != "pos"},
        )
        sub_cache["pos"] = jnp.zeros((1,), jnp.int32)
        logits, sub_cache = decode_step(params, sub_cache, tokens, cfg)
        # next token from the true last prompt position (pre-pad)
        last = logits[0, true_len - 1, : cfg.vocab]
        nxt = jnp.argmax(last).astype(jnp.int32)
        # write slot state back
        new_cache = {}
        for k, v in cache.items():
            if k == "pos":
                new_cache[k] = v.at[slot].set(true_len)
            elif v.ndim >= 2 and v.shape[1] == self.max_slots:
                new_cache[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, sub_cache[k].astype(v.dtype), slot, axis=1
                )
            else:
                new_cache[k] = v
        return nxt, new_cache

    # -- request flow --------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _bucket(self, plen: int) -> int:
        for b in self.prompt_buckets:
            if plen <= b:
                return b
        raise ValueError(f"prompt length {plen} exceeds largest bucket")

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            b = self._bucket(plen)
            padded = np.zeros((1, b), np.int32)
            padded[0, :plen] = req.prompt
            t0 = time.perf_counter()
            nxt, self.cache = self._prefill_exec[b](
                self.params, jnp.asarray(padded), self.cache,
                jnp.int32(slot), jnp.int32(plen),
            )
            self.stats.prefill_s += time.perf_counter() - t0
            req.t_first = time.perf_counter()
            req.generated.append(int(nxt))
            self._next_tok[slot, 0] = int(nxt)
            self.slots[slot] = req

    def step(self) -> None:
        """One engine iteration: admit + one decode step for all live slots."""
        self._admit()
        live = [s for s in range(self.max_slots) if self.slots[s] is not None]
        if not live:
            return
        t0 = time.perf_counter()
        nxt, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self._next_tok)
        )
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.steps += 1
        nxt_np = np.asarray(nxt)
        for s in live:
            req = self.slots[s]
            req.generated.append(int(nxt_np[s, 0]))
            self._next_tok[s, 0] = nxt_np[s, 0]
            self.stats.tokens_out += 1
            pos_full = len(req.prompt) + len(req.generated)
            if len(req.generated) >= req.max_new_tokens or pos_full >= self.max_len - 1:
                req.done = True
                req.t_done = time.perf_counter()
                self.slots[s] = None
                # reset the slot's write offset for the next occupant
                self.cache["pos"] = self.cache["pos"].at[s].set(0)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        for _ in range(max_steps):
            before = [r for r in self.slots if r is not None]
            self.step()
            finished.extend(r for r in before if r.done)
            if not self.queue and all(s is None for s in self.slots):
                break
        return finished
