"""Engine pickling/rehydration contract for the multi-process plane.

A live :class:`~repro.serving.engine.ServingEngine` cannot cross a
process boundary — its parameters, KV arenas, and sealed executables are
device state.  What *can* cross is the recipe: an :class:`EngineSpec` is
the small picklable object the parent ships to a worker process, which
calls :meth:`EngineSpec.build` **in the worker** so the AoT seal, the
weights, and the schedule cache all live (and die) with that device's
process.  The parent keeps only the spec and the scheduling-relevant
scalar it needs for admission control: ``max_slots``.

Contract:

* the spec (and everything it holds) must pickle — ship configs, seeds,
  and sizes, never arrays or engines;
* ``build(device_index, schedule_cache=None)`` runs in the worker
  process exactly once per registration; ``schedule_cache`` is the
  worker's shared per-device cache (pass it through so co-located lanes
  coalesce builds), and specs that ignore it may drop the keyword;
* ``max_slots`` must equal the built engine's slot capacity — the
  parent's lane proxy uses it for ``free_slots`` bookkeeping.
"""

from __future__ import annotations

import dataclasses
import pickle
from typing import Any, Optional, Union


def pickle_spec(spec: "EngineSpec") -> bytes:
    """Serialize an engine recipe for a process boundary or the request
    journal, verifying the round trip.

    Returns the pickle bytes after confirming they load back — a spec
    that captures an unpicklable closure or a live engine must fail
    loudly HERE, on the registering thread, not later inside a worker
    spawn or a journal recovery where the stack no longer points at the
    culprit.  Raises :class:`TypeError` with the offending spec named."""
    try:
        blob = pickle.dumps(spec)
        pickle.loads(blob)
    except Exception as exc:
        raise TypeError(
            f"engine spec {spec!r} is not picklable (specs must hold "
            f"configs/seeds/sizes, never arrays, engines, or closures): {exc}"
        ) from exc
    return blob


class EngineSpec:
    """Base rehydration recipe: subclass and implement :meth:`build`.

    ``max_slots`` (class or instance attribute) is read by the parent
    for slot accounting; everything else is yours."""

    max_slots: int = 4

    def build(self, device_index: int, schedule_cache: Any = None) -> Any:
        """Construct the engine in the worker process on ``device_index``."""
        raise NotImplementedError


@dataclasses.dataclass
class ServingEngineSpec(EngineSpec):
    """The real-model recipe: architecture name + sizes + init seed.

    ``build`` resolves the config (``smoke=True`` keeps worker start-up
    CI-sized), initializes parameters from ``seed``, places the engine on
    the worker's device, and seals schedules through the worker's shared
    cache — so registration cost is paid in the worker, and parent
    steppers still never compile."""

    arch: str = "stablelm-1.6b"
    max_slots: int = 4
    max_len: int = 128
    bucketing: Union[str, tuple] = "pow2:8:32"
    seed: int = 0
    smoke: bool = True
    dtype: Optional[str] = "float32"

    def build(self, device_index: int, schedule_cache: Any = None) -> Any:
        """Rehydrate a :class:`~repro.serving.engine.ServingEngine`."""
        import jax

        import repro.configs as C
        from repro.models import init_model

        from .engine import ServingEngine

        cfg = C.get(self.arch, smoke=self.smoke)
        if self.dtype is not None:
            cfg = dataclasses.replace(cfg, dtype=self.dtype)
        params, _ = init_model(jax.random.key(self.seed), cfg)
        devices = jax.devices()
        device = devices[device_index % len(devices)]
        return ServingEngine(
            cfg, params, max_slots=self.max_slots, max_len=self.max_len,
            bucketing=self.bucketing, schedule_cache=schedule_cache,
            device=device,
        )
