from .engine import EngineStats, Request, ServingEngine

__all__ = ["EngineStats", "Request", "ServingEngine"]
