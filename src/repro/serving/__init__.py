"""repro.serving: continuous-batching inference over AoT-sealed schedules.

:class:`ServingEngine` runs iteration-level continuous batching over
prefill/decode executables sealed once through a shared
``repro.dispatch.ScheduleCache``; :class:`Request` is the unit of traffic
(also what the dispatch layer routes) and :class:`EngineStats` the
per-engine counter block.  :class:`EngineSpec` / :class:`ServingEngineSpec`
are the picklable rehydration recipes the multi-process worker plane
ships across process boundaries (engines themselves never pickle).
"""

from .engine import EngineStats, Request, ServingEngine
from .spec import EngineSpec, ServingEngineSpec

__all__ = [
    "EngineSpec",
    "EngineStats",
    "Request",
    "ServingEngine",
    "ServingEngineSpec",
]
