"""repro.serving: continuous-batching inference over AoT-sealed schedules.

:class:`ServingEngine` runs iteration-level continuous batching over
prefill/decode executables sealed once through a shared
``repro.dispatch.ScheduleCache``; :class:`Request` is the unit of traffic
(also what the dispatch layer routes) and :class:`EngineStats` the
per-engine counter block.
"""

from .engine import EngineStats, Request, ServingEngine

__all__ = ["EngineStats", "Request", "ServingEngine"]
