from .sharding import (
    DEFAULT_RULES,
    LONG_CONTEXT_OVERRIDES,
    constrain,
    gather_fsdp,
    logical_to_pspec,
    parse_axes,
    tree_shardings,
    use_sharding_ctx,
)

__all__ = [
    "DEFAULT_RULES", "LONG_CONTEXT_OVERRIDES", "constrain", "gather_fsdp",
    "logical_to_pspec", "parse_axes", "tree_shardings", "use_sharding_ctx",
]
