"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Parameters and activations are annotated with *logical* axis names; a rule
table maps logical axes to mesh axes.  An axis is sharded only when its size
divides the product of the mapped mesh axes — otherwise it is replicated
(e.g. phi4's 24 query heads on a 16-way model axis).

``use_sharding_ctx(mesh, rules)`` installs a context so model code can call
``constrain(x, "batch", "seq", "embed")`` without threading the mesh through
every function; outside a context the call is a no-op (CPU smoke tests).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),   # weight-shard dim for FSDP/ZeRO
    "embed": None,              # activations' feature dim: replicated
    "seq": None,
    "kv_seq": None,             # decode KV cache sequence dim
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "layers": None,
    "state": None,
    "lora": None,
}

# Rules for the long-context decode shape: batch=1 so the data axis instead
# shards the KV-cache sequence dimension (sequence/context parallelism).
LONG_CONTEXT_OVERRIDES: dict[str, Any] = {
    "kv_seq": ("pod", "data"),
    "batch": None,
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: dict[str, Any]


_tls = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def use_sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = current_ctx()
    if mesh is None:
        _tls.ctx = None
    else:
        r = dict(DEFAULT_RULES)
        if rules:
            r.update(rules)
        _tls.ctx = ShardingCtx(mesh=mesh, rules=r)
    try:
        yield _tls.ctx
    finally:
        _tls.ctx = prev


def _mesh_axes_size(mesh: Mesh, spec_entry: Any) -> int:
    if spec_entry is None:
        return 1
    axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return size


def _filter_entry(mesh: Mesh, entry: Any) -> Any:
    """Drop mesh axes absent from this mesh (e.g. 'pod' on single-pod)."""
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    present = tuple(a for a in axes if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def logical_to_pspec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict[str, Any],
) -> P:
    """Map logical axes to a PartitionSpec, respecting divisibility and
    never using one mesh axis twice."""
    entries = []
    used: set[str] = set()
    for ax_name, dim in zip(logical_axes, shape):
        entry = None
        if ax_name is not None:
            entry = _filter_entry(mesh, rules.get(ax_name))
            if entry is not None:
                axes = entry if isinstance(entry, tuple) else (entry,)
                if any(a in used for a in axes):
                    entry = None
                else:
                    size = _mesh_axes_size(mesh, entry)
                    if size <= 1 or dim % size != 0:
                        entry = None
                    else:
                        used.update(axes)
        entries.append(entry)
    return P(*entries)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via the installed context (no-op without)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = logical_to_pspec(logical_axes, x.shape, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def gather_fsdp(x: jax.Array, *logical_axes: Optional[str],
                group: str = "all") -> jax.Array:
    """FSDP weight-gather at use: re-constrain a parameter with its ``fsdp``
    dims replicated, so contractions see a full (weight-gathered) operand.

    Without this, GSPMD may partially contract over the fsdp-sharded dim and
    all-reduce the *activation*-sized result — orders of magnitude more
    collective bytes than gathering the weight (EXPERIMENTS.md §Perf,
    deepseek hillclimb, iteration 3).  Opt-in via the rules entry
    ``{"gather_fsdp": "all" | "moe" | "attn" | "ffn"}`` so per-site effects
    are measurable; off by default (the recorded baseline behavior).
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    mode = ctx.rules.get("gather_fsdp", "off")
    if mode != "all" and mode != group:
        return x
    axes = tuple(None if a == "fsdp" else a for a in logical_axes)
    return constrain(x, *axes)


def named_sharding_for(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[dict[str, Any]] = None,
) -> NamedSharding:
    r = dict(DEFAULT_RULES)
    if rules:
        r.update(rules)
    return NamedSharding(mesh, logical_to_pspec(logical_axes, shape, mesh, r))


def parse_axes(spec: str) -> tuple[Optional[str], ...]:
    """Parse a whitespace-separated logical-axes string; ``_`` = replicated.

    Axes strings (not tuples) keep the axes pytree the same shape as the
    params pytree, so the two can be tree_mapped together.
    """
    if not spec:
        return ()
    return tuple(None if tok == "_" else tok for tok in spec.split())


def tree_shardings(
    params_shapes: Any,
    params_axes: Any,
    mesh: Mesh,
    rules: Optional[dict[str, Any]] = None,
) -> Any:
    """Map a pytree of ShapeDtypeStructs + a matching pytree of logical-axis
    strings (see :func:`parse_axes`) to a pytree of NamedShardings."""
    r = dict(DEFAULT_RULES)
    if rules:
        r.update(rules)

    def one(sds, axes_str):
        axes = parse_axes(axes_str)
        if len(axes) != len(sds.shape):
            raise ValueError(
                f"axes {axes_str!r} rank {len(axes)} != shape {sds.shape}"
            )
        return NamedSharding(mesh, logical_to_pspec(axes, sds.shape, mesh, r))

    return jax.tree_util.tree_map(one, params_shapes, params_axes)
